//! The generic resilient-execution engine: any [`Workload`] through the
//! full `--resilience` × `--cluster` fault-model matrix.
//!
//! This is `stencil::driver`'s DAG loop, fault wiring, repair logic, and
//! reporting factored out of the 1D-stencil specifics: the driver owned
//! ring-shaped dependencies and fixed wavefront widths; the engine takes
//! both from [`Workload::layer_tasks`] ([`TaskSpec`] declares each
//! task's dependency slots) and so runs fork-join trees, global
//! reductions, and pipelines through byte-for-byte the same recovery
//! machinery. Six routes, selected exactly like the driver's:
//!
//! * pool / cluster / proc (plain or decorated): the shared layered-DAG
//!   loop, every task launched through a [`BuiltExecutor`] route;
//! * pool / cluster / proc checkpoint
//!   (`--resilience checkpoint:K[:backend]`): the windowed
//!   snapshot/repair loop — snapshot layers every K windows,
//!   barrier-triggered cone repair, eager barriers on kills.
//!
//! The proc routes (`--cluster proc:N`) swap the simulated substrate
//! for real spawned worker processes ([`crate::distributed::proc`]):
//! same DAG loop, same decorators, but kills are literal `SIGKILL`s and
//! death is a heartbeat verdict, so the reported detection and recovery
//! latencies are honest wall-clock measurements.
//!
//! Reports are uniform ([`RunReport`]): survival rate, recovery
//! latency, `tasks_reexecuted`, snapshot traffic — same semantics as
//! [`StencilReport`](crate::stencil::StencilReport) so the zoo's
//! numbers compare directly against Table II / Fig 4–5.

use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::agas::LocalityId;
use crate::checkpoint::store::SnapshotStore;
use crate::checkpoint::{DiskSnapshotStore, MemorySnapshotStore};
use crate::distributed::{
    Cluster, ClusterExecutor, ClusterSpec, KillEvent, ProcCluster, ProcExec, ProcMirrorStore,
    ProcSpec, RemoteWorkload,
};
use crate::error::{TaskError, TaskResult};
use crate::failure::{FaultInjector, SdcInjector};
use crate::future::Future;
use crate::metrics::Timer;
use crate::resilience::checkpoint::{
    AgasSnapshotStore, CheckpointExecutor, SnapshotCounts, Snapshots,
};
use crate::resilience::executor::{
    BuiltExecutor, PolicySpec, PoolExecutor, SnapshotBackend, TaskLauncher, TaskValidator,
};
use crate::runtime_handle::Runtime;
use crate::stencil::kernel;
use crate::stencil::{Chunk, LocalityReport};

use super::{TaskBody, TaskSpec, Workload};

/// The adaptive replay route's minimum budget — same value and rationale
/// as the stencil driver's (`stencil::driver::ADAPTIVE_FLOOR`): replay
/// attempts cost nothing until a task fails, and a low floor would let
/// early tasks exhaust before the policy has observed anything.
const ADAPTIVE_FLOOR: usize = 5;

/// Replication factor of the AGAS snapshot backend on the cluster
/// checkpoint route: two replicas on distinct live localities so a
/// single locality death never loses a snapshot.
const AGAS_SNAPSHOT_REPLICAS: usize = 2;

/// Attempt budget for one repair execution during checkpoint recovery
/// (for injected failures re-striking the repair itself; repairs route
/// over live localities only).
const REPAIR_ATTEMPTS: usize = 5;

/// How a workload runs: the fault model and the resilience answer to
/// it, everything the CLI's `rhpx run` flags map onto.
#[derive(Clone)]
pub struct RunParams {
    /// Executor-routed resilience policy (`--resilience`); `None` runs
    /// the undecorated control arm.
    pub resilience: Option<PolicySpec>,
    /// When set, tasks place round-robin across a simulated cluster and
    /// the spec's fault schedule kills localities mid-run
    /// (`--cluster N:kill=STEP@LOC`).
    pub cluster: Option<ClusterSpec>,
    /// When set, tasks execute on real spawned worker *processes*
    /// (`--cluster proc:N[:kill=STEP@LOC][:crash=N@LOC]`): kills are a
    /// literal `SIGKILL` of a child PID and death is decided by missed
    /// heartbeats, not bookkeeping. Mutually exclusive with `cluster`.
    pub proc: Option<ProcSpec>,
    /// Exception-style failures: the paper's error-rate factor *x*,
    /// P(failure per task) = e^{-x}. `None` disables injection.
    pub error_rate: Option<f64>,
    /// Silent-data-corruption probability per task: each completed task
    /// body suffers a mantissa bit-flip ([`SdcInjector`]) with this
    /// probability. Only checksum validation can catch it.
    pub sdc_rate: Option<f64>,
    /// Checksum validation on/off. The SDC control arm turns this off
    /// to demonstrate corruption flowing through undetected.
    pub validate: bool,
    pub seed: u64,
}

impl Default for RunParams {
    fn default() -> Self {
        RunParams {
            resilience: None,
            cluster: None,
            proc: None,
            error_rate: None,
            sdc_rate: None,
            validate: true,
            seed: 0x1CE,
        }
    }
}

/// Outcome of a workload run — field-for-field the semantics of
/// [`StencilReport`](crate::stencil::StencilReport), plus the workload
/// name, so every zoo member reports survival, recovery latency, and
/// re-execution work identically.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub workload: String,
    pub mode: String,
    /// The substrate tasks ran on: `pool(N)` or `cluster(N)`.
    pub launcher: String,
    pub wall_secs: f64,
    /// Tasks the DAG launched (layer widths summed).
    pub tasks: usize,
    /// Slots in the final wavefront (the survival denominator).
    pub subdomains: usize,
    pub failures_injected: u64,
    pub silent_corruptions: u64,
    /// Final-wavefront slots whose resilient launch ultimately failed.
    pub launch_errors: u64,
    pub kills_applied: usize,
    /// Mean kill→barrier-drain time on cluster routes; mean repair-pass
    /// duration on the pool checkpoint route; on the proc route, mean
    /// verdict→re-completion time of re-materialized in-flight tasks.
    pub recovery_latency_secs: Option<f64>,
    /// Proc route only: mean SIGKILL→heartbeat-verdict time. `None` on
    /// the simulated routes (a scripted kill is "detected" by fiat) and
    /// for self-crash arms (nobody marked a kill instant).
    pub detection_latency_secs: Option<f64>,
    pub localities: Vec<LocalityReport>,
    /// Work beyond one execution per DAG node (retries, replicas,
    /// repairs, dead-locality rejections) — see
    /// [`StencilReport::tasks_reexecuted`](crate::stencil::StencilReport::tasks_reexecuted).
    pub tasks_reexecuted: u64,
    pub snapshots: SnapshotCounts,
    pub final_checksum: f64,
}

impl RunReport {
    /// Fraction of final-wavefront slots that survived.
    pub fn survival_rate(&self) -> f64 {
        if self.subdomains == 0 {
            return 1.0;
        }
        (self.subdomains as u64).saturating_sub(self.launch_errors) as f64
            / self.subdomains as f64
    }
}

/// Run a workload; returns the gathered final wavefront (poisoned slots
/// as empty placeholders) and the report.
///
/// Route selection is identical to `stencil::driver::run`: the
/// checkpoint policy owns its own window/snapshot/repair loop; every
/// other policy goes through the shared DAG loop. Pool routes where
/// *every* final slot is poisoned return the first error; on cluster
/// routes total poisoning is a legitimate measured outcome (survival
/// rate 0) and the report is always returned.
pub fn run(
    rt: &Runtime,
    w: &dyn Workload,
    params: &RunParams,
) -> TaskResult<(Vec<f64>, RunReport)> {
    if let Some(PolicySpec::Checkpoint { every, backend }) = params.resilience {
        if w.window() == 0 {
            return Err(TaskError::Runtime(
                "checkpoint:K needs window > 0: snapshots are taken at window barriers".into(),
            ));
        }
        return match (&params.proc, &params.cluster) {
            (Some(_), Some(_)) => Err(substrate_conflict()),
            (Some(pspec), None) => run_proc_ckpt(w, params, pspec, every, backend),
            (None, None) => run_pool_ckpt(rt, w, params, every, backend),
            (None, Some(spec)) => run_cluster_ckpt(w, params, spec, every, backend),
        };
    }
    match (&params.proc, &params.cluster) {
        (Some(_), Some(_)) => Err(substrate_conflict()),
        (Some(pspec), None) => run_proc(w, params, pspec),
        (None, None) => run_pool(rt, w, params),
        (None, Some(spec)) => run_cluster(w, params, spec),
    }
}

fn substrate_conflict() -> TaskError {
    TaskError::Runtime(
        "the simulated cluster and the proc substrate are mutually exclusive".into(),
    )
}

/// The per-run fault wiring, shared by every route: exception injector,
/// SDC injector, and the body-run counter (pool-route re-execution
/// accounting), cloned into each task body.
#[derive(Clone)]
struct FaultWiring {
    injector: FaultInjector,
    sdc: SdcInjector,
    runs: Arc<AtomicU64>,
}

impl FaultWiring {
    fn new(params: &RunParams) -> Self {
        FaultWiring {
            injector: FaultInjector::new(params.error_rate.unwrap_or(0.0), params.seed),
            sdc: SdcInjector::new(params.sdc_rate, params.seed ^ 0xDEAD),
            runs: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Wrap a pure workload body with the fault model: count the run,
    /// draw the injector, run the math, attach the checksum of the
    /// *correct* output, then maybe bit-flip it — so a landed corruption
    /// is exactly a checksum mismatch, the §III-B silent error.
    fn wrap(
        &self,
        body: &TaskBody,
    ) -> impl Fn(&[Chunk]) -> TaskResult<Chunk> + Clone + Send + Sync + 'static {
        let injector = self.injector.clone();
        let sdc = self.sdc.clone();
        let runs = Arc::clone(&self.runs);
        let body = Arc::clone(body);
        move |vals: &[Chunk]| -> TaskResult<Chunk> {
            runs.fetch_add(1, Ordering::Relaxed);
            injector.draw("workload-task")?;
            let mut out = body(vals)?;
            let cksum = kernel::checksum(&out);
            if sdc.maybe_corrupt(&mut out) {
                crate::trace::emit(crate::trace::EventKind::SdcFlip, sdc.count(), 0);
            }
            Ok(Chunk::with_checksum(out, cksum))
        }
    }
}

/// What the shared DAG loop produced.
struct DagOutcome {
    /// Final wavefront, poisoned slots as empty placeholders (keeping
    /// the gather shape; an empty chunk contributes 0 to the checksum).
    finals: Vec<Chunk>,
    /// Final wavefront width (the survival denominator).
    width: usize,
    /// Tasks launched across all layers.
    tasks: usize,
    launch_errors: u64,
    first_error: Option<TaskError>,
}

/// The shared layered-DAG loop — `run_dag` generalized: wavefront
/// widths and dependency slots come from the workload's [`TaskSpec`]s
/// instead of a hardcoded ring. `before_task` sees the global task
/// index (the fault schedule's clock); `after_barrier` runs after each
/// window barrier drains.
fn run_layers<S, L, B>(
    w: &dyn Workload,
    mut before_task: S,
    mut launch: L,
    mut after_barrier: B,
) -> DagOutcome
where
    S: FnMut(usize),
    L: FnMut(&TaskSpec, Vec<Future<Chunk>>) -> Future<Chunk>,
    B: FnMut(),
{
    let window = w.window().max(1);
    let layers = w.layers();
    let mut futs: Vec<Future<Chunk>> =
        w.initial().into_iter().map(|c| Future::ready(Ok(c))).collect();
    let mut task_idx = 0usize;

    for layer in 0..layers {
        let specs = w.layer_tasks(layer);
        let mut next: Vec<Future<Chunk>> = Vec::with_capacity(specs.len());
        for spec in &specs {
            before_task(task_idx);
            task_idx += 1;
            let deps: Vec<Future<Chunk>> =
                spec.deps.iter().map(|&d| futs[d].clone()).collect();
            next.push(launch(spec, deps));
        }
        futs = next;
        if (layer + 1) % window == 0 {
            // Bound in-flight work: block until this wavefront is done.
            for f in &futs {
                f.wait();
            }
            after_barrier();
        }
    }

    let width = futs.len();
    let mut launch_errors = 0u64;
    let mut first_error: Option<TaskError> = None;
    let mut finals: Vec<Chunk> = Vec::with_capacity(width);
    for f in futs {
        match f.get() {
            Ok(chunk) => finals.push(chunk),
            Err(e) => {
                launch_errors += 1;
                if first_error.is_none() {
                    first_error = Some(e);
                }
                finals.push(Chunk::new(Vec::new()));
            }
        }
    }
    DagOutcome { finals, width, tasks: task_idx, launch_errors, first_error }
}

/// Concatenate the final wavefront (the generic "gather").
fn gather(finals: &[Chunk]) -> Vec<f64> {
    finals.iter().flat_map(|c| c.data.iter().copied()).collect()
}

/// Global checksum of the final wavefront — same definition as
/// [`Domain::global_checksum`](crate::stencil::Domain::global_checksum).
fn checksum_of(finals: &[Chunk]) -> f64 {
    finals.iter().map(|c| kernel::checksum(&c.data)).sum()
}

/// Mean of a latency sample, `None` when empty.
fn mean_secs(latencies: &[f64]) -> Option<f64> {
    if latencies.is_empty() {
        None
    } else {
        Some(latencies.iter().sum::<f64>() / latencies.len() as f64)
    }
}

fn mode_label(params: &RunParams) -> String {
    params
        .resilience
        .map(|p| p.label())
        .unwrap_or_else(|| "pure_dataflow".into())
}

/// Cluster-route re-execution accounting: locality attempts (bodies
/// executed + dead-locality rejections + in-queue deaths) in excess of
/// one per DAG node. A task lost from a corpse's queue re-materializes
/// on a survivor as a fresh routing, so counting `tasks_lost` here keeps
/// the invariant Σ(executed + rejected + lost) = routings.
fn cluster_reexecuted(localities: &[LocalityReport], tasks: usize) -> u64 {
    let attempts: usize = localities
        .iter()
        .map(|l| l.tasks_executed + l.tasks_rejected + l.tasks_lost)
        .sum();
    (attempts as u64).saturating_sub(tasks as u64)
}

/// Per-locality placement/survival breakdown of a finished cluster run.
fn locality_reports(cluster: &Cluster, kills_applied: &[KillEvent]) -> Vec<LocalityReport> {
    (0..cluster.len())
        .map(|i| {
            let loc = cluster.locality(LocalityId(i));
            LocalityReport {
                id: i,
                tasks_executed: loc.tasks_executed(),
                tasks_rejected: loc.tasks_rejected(),
                tasks_lost: loc.tasks_lost(),
                alive_at_end: loc.is_alive(),
                killed_at_task: kills_applied.iter().find(|e| e.loc.0 == i).map(|e| e.step),
            }
        })
        .collect()
}

/// Launch one task through an executor route over any launcher — the
/// seam that keeps the engine substrate-generic.
fn launch_via<E: TaskLauncher>(
    route: &BuiltExecutor<E>,
    spec: &TaskSpec,
    wiring: &FaultWiring,
    validate: bool,
    tol: f64,
    deps: Vec<Future<Chunk>>,
) -> Future<Chunk> {
    let body = wiring.wrap(&spec.body);
    route.dataflow_validate(
        move |c: &Chunk| !validate || c.verify(tol),
        move |v: &[Chunk]| body(v),
        deps,
    )
}

/// The single-runtime route.
fn run_pool(
    rt: &Runtime,
    w: &dyn Workload,
    params: &RunParams,
) -> TaskResult<(Vec<f64>, RunReport)> {
    let wiring = FaultWiring::new(params);
    let route: BuiltExecutor = match params.resilience {
        Some(p) => p.build(rt, w.name(), ADAPTIVE_FLOOR),
        None => BuiltExecutor::Single(PoolExecutor::new(rt)),
    };
    let (validate, tol) = (params.validate, w.tol());

    let timer = Timer::start();
    let out = run_layers(
        w,
        |_task_idx| {},
        |spec, deps| launch_via(&route, spec, &wiring, validate, tol, deps),
        || {},
    );
    let wall = timer.elapsed_secs();

    let report = RunReport {
        workload: w.name().into(),
        mode: mode_label(params),
        launcher: route.base_label(),
        wall_secs: wall,
        tasks: out.tasks,
        subdomains: out.width,
        failures_injected: wiring.injector.counters().injected(),
        silent_corruptions: wiring.sdc.count(),
        launch_errors: out.launch_errors,
        kills_applied: 0,
        recovery_latency_secs: None,
        detection_latency_secs: None,
        localities: Vec::new(),
        tasks_reexecuted: wiring
            .runs
            .load(Ordering::Relaxed)
            .saturating_sub(out.tasks as u64),
        snapshots: SnapshotCounts::default(),
        final_checksum: checksum_of(&out.finals),
    };
    match out.first_error {
        Some(e) if out.launch_errors as usize == out.width => Err(e),
        _ => Ok((gather(&out.finals), report)),
    }
}

/// The distributed route: the same DAG, every task launched through a
/// cluster-backed executor, with the spec's fault schedule applied at
/// deterministic task indices.
fn run_cluster(
    w: &dyn Workload,
    params: &RunParams,
    spec: &ClusterSpec,
) -> TaskResult<(Vec<f64>, RunReport)> {
    let wiring = FaultWiring::new(params);
    let cluster = spec.build();
    // `--resilience drain` relies on the lineage drain alone: tasks must
    // never be *placed* on a corpse (there is nothing to reject them),
    // so the substrate routes over live localities only.
    let exec = if params.resilience.map(|p| p.routes_alive_only()).unwrap_or(false) {
        ClusterExecutor::alive_routed(&cluster)
    } else {
        ClusterExecutor::new(&cluster)
    };
    let route: BuiltExecutor<ClusterExecutor> = match params.resilience {
        Some(p) => p.build_over(exec, w.name(), ADAPTIVE_FLOOR),
        None => BuiltExecutor::Single(exec),
    };
    let (validate, tol) = (params.validate, w.tol());

    let mut schedule = spec.schedule.clone();
    let mut kills_applied: Vec<KillEvent> = Vec::new();
    // Kills awaiting their recovery-latency measurement (taken at the
    // next window barrier, when the wavefront containing the fault has
    // provably drained).
    let pending: RefCell<Vec<Timer>> = RefCell::new(Vec::new());
    let mut latencies: Vec<f64> = Vec::new();

    let timer = Timer::start();
    let out = run_layers(
        w,
        |task_idx| {
            for ev in schedule.advance(task_idx, &cluster) {
                kills_applied.push(ev);
                pending.borrow_mut().push(Timer::start());
            }
        },
        |spec, deps| launch_via(&route, spec, &wiring, validate, tol, deps),
        || {
            for t in pending.borrow_mut().drain(..) {
                latencies.push(t.elapsed_secs());
            }
        },
    );
    // Kills in the final (un-barriered) window recover by the gather.
    for t in pending.borrow_mut().drain(..) {
        latencies.push(t.elapsed_secs());
    }
    let wall = timer.elapsed_secs();

    let localities = locality_reports(&cluster, &kills_applied);

    // When a kill actually drained queued tracked tasks, the direct
    // drain-to-reschedule measurement is the recovery latency (no window
    // barrier involved); the kill→barrier measure is the fallback for
    // kills that found an empty queue.
    let drain = cluster.drain_latency_secs();
    let recovery = if drain.is_empty() { mean_secs(&latencies) } else { mean_secs(&drain) };

    let report = RunReport {
        workload: w.name().into(),
        mode: mode_label(params),
        launcher: route.base_label(),
        wall_secs: wall,
        tasks: out.tasks,
        subdomains: out.width,
        failures_injected: wiring.injector.counters().injected(),
        silent_corruptions: wiring.sdc.count(),
        launch_errors: out.launch_errors,
        kills_applied: kills_applied.len(),
        recovery_latency_secs: recovery,
        detection_latency_secs: None,
        tasks_reexecuted: cluster_reexecuted(&localities, out.tasks),
        snapshots: SnapshotCounts::default(),
        localities,
        final_checksum: checksum_of(&out.finals),
    };
    Ok((gather(&out.finals), report))
}

// ---------------------------------------------------------------------
// The checkpoint/restart route (--resilience checkpoint:K)
// ---------------------------------------------------------------------

/// Snapshot key for the wavefront state of slot `j` after layer
/// `layer` (`-1` = the initial wavefront, persisted before the run so
/// the first period always has a durable restore base).
fn ckpt_key(layer: isize, j: usize) -> String {
    format!("ckpt_{layer}_{j}")
}

/// One launched layer retained for window repair: the futures *and* the
/// specs that produced them, so the repair pass can re-derive any
/// task's dependency slots and re-run its body. (The stencil driver
/// hardcoded the ring here; this is the piece that makes repair
/// shape-generic.)
struct LayerState {
    specs: Vec<TaskSpec>,
    futs: Vec<Future<Chunk>>,
}

/// What one checkpointed DAG run produced.
struct CkptOutcome {
    finals: Vec<Chunk>,
    width: usize,
    tasks: usize,
    launch_errors: u64,
    repair_latencies: Vec<f64>,
}

/// The checkpointed DAG loop — `run_ckpt_dag` generalized over layer
/// shape. Snapshot layers (every `every` windows, aligned to window
/// barriers) launch through
/// [`CheckpointExecutor::dataflow_checkpointed_validate`]; the current
/// window's layers are retained ([`LayerState`]) and every barrier runs
/// a repair pass over exactly the failed tasks; `before_task` returning
/// `true` (a fault event fired) forces an eager barrier after the
/// current layer.
fn run_ckpt_dag<E: TaskLauncher>(
    w: &dyn Workload,
    params: &RunParams,
    every: usize,
    exec: &CheckpointExecutor<E>,
    wiring: &FaultWiring,
    mut before_task: impl FnMut(usize) -> bool,
    mut after_barrier: impl FnMut(),
) -> TaskResult<CkptOutcome> {
    let window = w.window().max(1);
    let layers = w.layers();
    let period = every.max(1) * window;
    let snaps = Arc::clone(exec.snapshots());
    let (validate, tol) = (params.validate, w.tol());
    let validator: TaskValidator<Chunk> = Arc::new(move |c: &Chunk| !validate || c.verify(tol));
    let is_snap_layer =
        move |layer: isize| -> bool { layer == -1 || ((layer as usize) + 1) % period == 0 };

    // Durable restore base for failures in the first period.
    let initial = w.initial();
    for (j, c) in initial.iter().enumerate() {
        snaps.save_value(&ckpt_key(-1, j), c)?;
    }

    // entry[j]: state at the layer just below the current window
    // (None = irreparably poisoned).
    let mut entry: Vec<Option<Chunk>> = initial.iter().cloned().map(Some).collect();
    let mut futs: Vec<Future<Chunk>> =
        initial.iter().map(|c| Future::ready(Ok(c.clone()))).collect();
    let mut grid: Vec<LayerState> = Vec::new();
    let mut win_start: usize = 0;
    let mut force_barrier = false;
    let mut repair_latencies: Vec<f64> = Vec::new();
    let mut task_idx = 0usize;

    for layer in 0..layers {
        let specs = w.layer_tasks(layer);
        let mut next: Vec<Future<Chunk>> = Vec::with_capacity(specs.len());
        for (j, spec) in specs.iter().enumerate() {
            if before_task(task_idx) {
                force_barrier = true;
            }
            task_idx += 1;
            let deps: Vec<Future<Chunk>> =
                spec.deps.iter().map(|&d| futs[d].clone()).collect();
            let body = wiring.wrap(&spec.body);
            let fut = if is_snap_layer(layer as isize) {
                exec.dataflow_checkpointed_validate(
                    &ckpt_key(layer as isize, j),
                    move |c: &Chunk| !validate || c.verify(tol),
                    move |v: &[Chunk]| body(v),
                    deps,
                )
            } else {
                exec.dataflow_validate(
                    move |c: &Chunk| !validate || c.verify(tol),
                    move |v: &[Chunk]| body(v),
                    deps,
                )
            };
            next.push(fut);
        }
        grid.push(LayerState { specs, futs: next.clone() });
        futs = next;

        let at_barrier = force_barrier || (layer + 1) % window == 0 || layer + 1 == layers;
        if !at_barrier {
            continue;
        }
        force_barrier = false;
        for f in &futs {
            f.wait();
        }
        let any_failed =
            grid.iter().any(|ls| ls.futs.iter().any(|f| f.get_copy().is_err()));
        if any_failed {
            let t = Timer::start();
            repair_window(exec, &snaps, &validator, wiring, &mut grid, &entry, win_start, is_snap_layer);
            repair_latencies.push(t.elapsed_secs());
            futs = grid.last().expect("barrier implies a launched layer").futs.clone();
        }
        // Advance the entry wavefront and trim the window state.
        entry = futs.iter().map(|f| f.get_copy().ok()).collect();
        grid.clear();
        win_start = layer + 1;
        after_barrier();
    }

    let width = futs.len();
    let mut launch_errors = 0u64;
    let mut finals: Vec<Chunk> = Vec::with_capacity(width);
    for f in futs {
        match f.get() {
            Ok(chunk) => finals.push(chunk),
            Err(_) => {
                launch_errors += 1;
                finals.push(Chunk::new(Vec::new()));
            }
        }
    }
    Ok(CkptOutcome { finals, width, tasks: task_idx, launch_errors, repair_latencies })
}

/// Repair one window in place: re-execute exactly the failed tasks,
/// layer by layer ascending, with dependencies drawn from
/// already-repaired values, surviving results, and (for the
/// window-entry layer) the snapshot store — the driver's repair pass
/// with the failure cone derived from each task's declared `deps`
/// instead of the stencil ring. Repaired snapshot-layer results are
/// re-persisted; tasks whose dependencies are irreparable keep their
/// poison.
#[allow(clippy::too_many_arguments)]
fn repair_window<E: TaskLauncher>(
    exec: &CheckpointExecutor<E>,
    snaps: &Arc<Snapshots>,
    validator: &TaskValidator<Chunk>,
    wiring: &FaultWiring,
    grid: &mut [LayerState],
    entry: &[Option<Chunk>],
    win_start: usize,
    is_snap_layer: impl Fn(isize) -> bool,
) {
    let entry_layer = win_start as isize - 1;
    let entry_snapshotted = is_snap_layer(entry_layer);
    let entry_width = entry.len();

    // Entry dependency state, restored lazily: only the slots a failed
    // first-layer task actually depends on are read back from the store.
    let mut needed = vec![false; entry_width];
    if let Some(ls) = grid.first() {
        for (j, f) in ls.futs.iter().enumerate() {
            if f.get_copy().is_err() {
                for &d in &ls.specs[j].deps {
                    needed[d] = true;
                }
            }
        }
    }
    let mut prev: Vec<Option<Chunk>> = (0..entry_width)
        .map(|j| {
            if entry_snapshotted && needed[j] {
                if let Some(c) =
                    snaps.restore_value::<Chunk>(&ckpt_key(entry_layer, j), Some(validator))
                {
                    return Some(c);
                }
                // Snapshot missing or lost: fall back to the surviving
                // in-memory wavefront below.
            }
            entry[j].clone()
        })
        .collect();

    for (t_rel, ls) in grid.iter_mut().enumerate() {
        let layer_t = (win_start + t_rel) as isize;
        let mut cur: Vec<Option<Chunk>> =
            ls.futs.iter().map(|f| f.get_copy().ok()).collect();
        // Gather this layer's repair jobs, then launch them all before
        // collecting any: failed tasks within a layer are independent,
        // so their repairs run concurrently on the substrate.
        let mut jobs: Vec<(usize, Vec<Chunk>)> = Vec::new();
        for j in 0..ls.futs.len() {
            if cur[j].is_some() {
                continue;
            }
            let deps: Vec<Option<Chunk>> =
                ls.specs[j].deps.iter().map(|&d| prev[d].clone()).collect();
            if deps.iter().any(|d| d.is_none()) {
                continue; // upstream irreparable: the poison stands
            }
            jobs.push((j, deps.into_iter().flatten().collect()));
        }
        let inflight: Vec<Future<Chunk>> = jobs
            .iter()
            .map(|(j, deps)| {
                let b = wiring.wrap(&ls.specs[*j].body);
                let d = deps.clone();
                exec.base().submit(Arc::new(move || b(&d)))
            })
            .collect();
        for ((j, deps), fut) in jobs.into_iter().zip(inflight) {
            let judge = |r: TaskResult<Chunk>| match r {
                Ok(c) if validator(&c) => Ok(c),
                Ok(_) => Err(TaskError::ValidationRejected),
                Err(e) => Err(e),
            };
            let mut outcome = judge(fut.get());
            // Serial retries only for the (rare) repair that failed
            // again — e.g. an injected error striking the repair itself.
            for _ in 1..REPAIR_ATTEMPTS {
                if outcome.is_ok() {
                    break;
                }
                let b = wiring.wrap(&ls.specs[j].body);
                let d = deps.clone();
                outcome = judge(exec.base().submit(Arc::new(move || b(&d))).get());
            }
            match outcome {
                Ok(c) => {
                    if is_snap_layer(layer_t) {
                        let _ = snaps.save_value(&ckpt_key(layer_t, j), &c);
                    }
                    ls.futs[j] = Future::ready(Ok(c.clone()));
                    cur[j] = Some(c);
                }
                Err(e) => {
                    ls.futs[j] = Future::ready(Err(e));
                    // cur[j] stays None: dependents keep their poison.
                }
            }
        }
        prev = cur;
    }
}

/// Fresh per-run directory for the disk snapshot backend.
fn disk_snapshot_dir() -> PathBuf {
    crate::checkpoint::store::unique_temp_dir("rhpx_zoo_snap")
}

/// The pool checkpoint route.
fn run_pool_ckpt(
    rt: &Runtime,
    w: &dyn Workload,
    params: &RunParams,
    every: usize,
    backend: SnapshotBackend,
) -> TaskResult<(Vec<f64>, RunReport)> {
    let (store, disk_dir): (Arc<dyn SnapshotStore>, Option<PathBuf>) = match backend {
        SnapshotBackend::Agas => {
            return Err(TaskError::Runtime(
                "--resilience checkpoint: the agas backend needs --cluster".into(),
            ))
        }
        SnapshotBackend::Disk => {
            let dir = disk_snapshot_dir();
            (Arc::new(DiskSnapshotStore::new(dir.clone())) as Arc<dyn SnapshotStore>, Some(dir))
        }
        SnapshotBackend::Auto | SnapshotBackend::Memory => {
            (Arc::new(MemorySnapshotStore::new()) as Arc<dyn SnapshotStore>, None)
        }
    };
    let wiring = FaultWiring::new(params);
    let exec = CheckpointExecutor::new(PoolExecutor::new(rt), store, w.name());

    let timer = Timer::start();
    let outcome = run_ckpt_dag(w, params, every, &exec, &wiring, |_| false, || {});
    let wall = timer.elapsed_secs();
    // Temp-dir cleanup must also run when the DAG errored out.
    if let Some(dir) = disk_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let out = outcome?;

    let report = RunReport {
        workload: w.name().into(),
        mode: mode_label(params),
        launcher: exec.base().base_label(),
        wall_secs: wall,
        tasks: out.tasks,
        subdomains: out.width,
        failures_injected: wiring.injector.counters().injected(),
        silent_corruptions: wiring.sdc.count(),
        launch_errors: out.launch_errors,
        kills_applied: 0,
        recovery_latency_secs: mean_secs(&out.repair_latencies),
        detection_latency_secs: None,
        localities: Vec::new(),
        tasks_reexecuted: wiring
            .runs
            .load(Ordering::Relaxed)
            .saturating_sub(out.tasks as u64),
        snapshots: exec.snapshots().counts(),
        final_checksum: checksum_of(&out.finals),
    };
    Ok((gather(&out.finals), report))
}

/// The cluster checkpoint route: tasks place over *live* localities
/// only, kills are propagated to the snapshot store (loss-on-kill), and
/// killed slots restore from the last window snapshot with only the
/// delta tasks re-executed.
fn run_cluster_ckpt(
    w: &dyn Workload,
    params: &RunParams,
    spec: &ClusterSpec,
    every: usize,
    backend: SnapshotBackend,
) -> TaskResult<(Vec<f64>, RunReport)> {
    let wiring = FaultWiring::new(params);
    let cluster = spec.build();
    let (store, disk_dir): (Arc<dyn SnapshotStore>, Option<PathBuf>) = match backend {
        SnapshotBackend::Auto | SnapshotBackend::Agas => (
            Arc::new(AgasSnapshotStore::new(&cluster, AGAS_SNAPSHOT_REPLICAS))
                as Arc<dyn SnapshotStore>,
            None,
        ),
        SnapshotBackend::Memory => {
            (Arc::new(MemorySnapshotStore::new()) as Arc<dyn SnapshotStore>, None)
        }
        SnapshotBackend::Disk => {
            let dir = disk_snapshot_dir();
            (Arc::new(DiskSnapshotStore::new(dir.clone())) as Arc<dyn SnapshotStore>, Some(dir))
        }
    };
    let exec =
        CheckpointExecutor::new(ClusterExecutor::alive_routed(&cluster), store, w.name());
    let snaps = Arc::clone(exec.snapshots());

    let mut schedule = spec.schedule.clone();
    let mut kills_applied: Vec<KillEvent> = Vec::new();
    let pending: RefCell<Vec<Timer>> = RefCell::new(Vec::new());
    let mut latencies: Vec<f64> = Vec::new();

    let timer = Timer::start();
    let outcome = run_ckpt_dag(
        w,
        params,
        every,
        &exec,
        &wiring,
        |task_idx| {
            let fired = schedule.advance(task_idx, &cluster);
            for ev in &fired {
                kills_applied.push(*ev);
                pending.borrow_mut().push(Timer::start());
                // Loss-on-kill: replicas homed on the corpse are
                // re-homed (live sibling exists) or dropped and counted.
                snaps.on_locality_killed(ev.loc);
            }
            // A fired kill forces an eager barrier after this layer, so
            // recovery starts before the cone crosses the window.
            !fired.is_empty()
        },
        || {
            for t in pending.borrow_mut().drain(..) {
                latencies.push(t.elapsed_secs());
            }
        },
    );
    for t in pending.borrow_mut().drain(..) {
        latencies.push(t.elapsed_secs());
    }
    let wall = timer.elapsed_secs();
    if let Some(dir) = disk_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let out = outcome?;

    let localities = locality_reports(&cluster, &kills_applied);

    let report = RunReport {
        workload: w.name().into(),
        mode: mode_label(params),
        launcher: exec.base().base_label(),
        wall_secs: wall,
        tasks: out.tasks,
        subdomains: out.width,
        failures_injected: wiring.injector.counters().injected(),
        silent_corruptions: wiring.sdc.count(),
        launch_errors: out.launch_errors,
        kills_applied: kills_applied.len(),
        recovery_latency_secs: mean_secs(&latencies),
        detection_latency_secs: None,
        tasks_reexecuted: cluster_reexecuted(&localities, out.tasks),
        snapshots: exec.snapshots().counts(),
        localities,
        final_checksum: checksum_of(&out.finals),
    };
    Ok((gather(&out.finals), report))
}

// ---------------------------------------------------------------------
// The process-backed routes (--cluster proc:N)
// ---------------------------------------------------------------------

/// Spawn the spec's worker fleet and the parent-side twin of the
/// workload (both built at the spec's milli-quantized scale, the shared
/// geometry authority).
fn proc_setup(
    w: &dyn Workload,
    pspec: &ProcSpec,
    resilient: bool,
) -> TaskResult<(ProcCluster, RemoteWorkload)> {
    let cluster = ProcCluster::start(pspec).map_err(TaskError::Runtime)?;
    let rw = RemoteWorkload::from_spec(w.name(), pspec, &cluster, resilient).ok_or_else(|| {
        TaskError::Runtime(format!("workload {:?} is not in the registry", w.name()))
    })?;
    Ok((cluster, rw))
}

/// Give the heartbeat monitor time to match every SIGKILL with a
/// verdict, so detection latency is reported even when the DAG finished
/// before the detector fired.
fn proc_settle(cluster: &ProcCluster, pspec: &ProcSpec) {
    let deadline_ms = pspec.heartbeat_ms * pspec.k_missed;
    cluster.settle_verdicts(Duration::from_millis(deadline_ms * 4 + 500));
}

/// Fold the workers' flight-recorder chunks (streamed frames merged with
/// the fsynced spool files, so a SIGKILLed worker's final events are
/// included) into the parent's trace session. No-op when tracing is off.
fn ingest_cluster_trace(cluster: &ProcCluster) {
    if !crate::trace::active() {
        return;
    }
    for (loc, events, dropped) in crate::trace::spool::per_locality(cluster.take_trace()) {
        crate::trace::ingest_remote(loc, events, dropped);
    }
}

/// The process-backed route: the same DAG loop, every task body a
/// remote call onto a spawned worker process, the spec's schedule fired
/// as real `SIGKILL`s at the same task-index clock the simulated route
/// uses. Death is decided by the heartbeat monitor, never assumed —
/// which is what makes the reported detection latency honest.
fn run_proc(
    w: &dyn Workload,
    params: &RunParams,
    pspec: &ProcSpec,
) -> TaskResult<(Vec<f64>, RunReport)> {
    let wiring = FaultWiring::new(params);
    let resilient = params.resilience.is_some();
    let (cluster, rw) = proc_setup(w, pspec, resilient)?;
    let exec = ProcExec::new(&cluster);
    let route: BuiltExecutor<ProcExec> = match params.resilience {
        Some(p) => p.build_over(exec, w.name(), ADAPTIVE_FLOOR),
        None => BuiltExecutor::Single(exec),
    };
    let (validate, tol) = (params.validate, rw.tol());

    let mut kills_applied: Vec<KillEvent> = Vec::new();
    let pending: RefCell<Vec<Timer>> = RefCell::new(Vec::new());
    let mut latencies: Vec<f64> = Vec::new();

    let timer = Timer::start();
    let out = run_layers(
        &rw,
        |task_idx| {
            for ev in cluster.advance_schedule(task_idx) {
                kills_applied.push(ev);
                pending.borrow_mut().push(Timer::start());
            }
        },
        |spec, deps| launch_via(&route, spec, &wiring, validate, tol, deps),
        || {
            for t in pending.borrow_mut().drain(..) {
                latencies.push(t.elapsed_secs());
            }
        },
    );
    for t in pending.borrow_mut().drain(..) {
        latencies.push(t.elapsed_secs());
    }
    let wall = timer.elapsed_secs();
    proc_settle(&cluster, pspec);
    ingest_cluster_trace(&cluster);

    let localities = cluster.locality_reports(&kills_applied);
    let drain = cluster.drain_latency_secs();
    let recovery = if drain.is_empty() { mean_secs(&latencies) } else { mean_secs(&drain) };

    let report = RunReport {
        workload: w.name().into(),
        mode: mode_label(params),
        launcher: route.base_label(),
        wall_secs: wall,
        tasks: out.tasks,
        subdomains: out.width,
        failures_injected: wiring.injector.counters().injected(),
        silent_corruptions: wiring.sdc.count(),
        launch_errors: out.launch_errors,
        kills_applied: kills_applied.len(),
        recovery_latency_secs: recovery,
        detection_latency_secs: mean_secs(&cluster.detection_latency_secs()),
        tasks_reexecuted: cluster_reexecuted(&localities, out.tasks),
        snapshots: SnapshotCounts::default(),
        localities,
        final_checksum: checksum_of(&out.finals),
    };
    Ok((gather(&out.finals), report))
}

/// The process-backed checkpoint route: snapshots live in the parent's
/// authoritative store and are mirrored onto workers over the wire
/// ([`ProcMirrorStore`]); a scheduled kill re-homes the corpse's mirrors
/// and forces an eager barrier, exactly like the AGAS route.
fn run_proc_ckpt(
    w: &dyn Workload,
    params: &RunParams,
    pspec: &ProcSpec,
    every: usize,
    backend: SnapshotBackend,
) -> TaskResult<(Vec<f64>, RunReport)> {
    let wiring = FaultWiring::new(params);
    let (cluster, rw) = proc_setup(w, pspec, true)?;
    let (store, disk_dir): (Arc<dyn SnapshotStore>, Option<PathBuf>) = match backend {
        SnapshotBackend::Agas => {
            return Err(TaskError::Runtime(
                "checkpoint: the agas backend is simulation-only; the proc route mirrors \
                 snapshots onto workers by default"
                    .into(),
            ))
        }
        SnapshotBackend::Disk => {
            let dir = disk_snapshot_dir();
            (Arc::new(DiskSnapshotStore::new(dir.clone())) as Arc<dyn SnapshotStore>, Some(dir))
        }
        SnapshotBackend::Auto | SnapshotBackend::Memory => {
            (Arc::new(ProcMirrorStore::new(&cluster)) as Arc<dyn SnapshotStore>, None)
        }
    };
    let exec = CheckpointExecutor::new(ProcExec::new(&cluster), store, w.name());
    let snaps = Arc::clone(exec.snapshots());

    let mut kills_applied: Vec<KillEvent> = Vec::new();
    let pending: RefCell<Vec<Timer>> = RefCell::new(Vec::new());
    let mut latencies: Vec<f64> = Vec::new();

    let timer = Timer::start();
    let outcome = run_ckpt_dag(
        &rw,
        params,
        every,
        &exec,
        &wiring,
        |task_idx| {
            let fired = cluster.advance_schedule(task_idx);
            for ev in &fired {
                kills_applied.push(*ev);
                pending.borrow_mut().push(Timer::start());
                snaps.on_locality_killed(ev.loc);
            }
            !fired.is_empty()
        },
        || {
            for t in pending.borrow_mut().drain(..) {
                latencies.push(t.elapsed_secs());
            }
        },
    );
    for t in pending.borrow_mut().drain(..) {
        latencies.push(t.elapsed_secs());
    }
    let wall = timer.elapsed_secs();
    if let Some(dir) = disk_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let out = outcome?;
    proc_settle(&cluster, pspec);
    ingest_cluster_trace(&cluster);

    let localities = cluster.locality_reports(&kills_applied);
    let drain = cluster.drain_latency_secs();
    let recovery = if drain.is_empty() { mean_secs(&latencies) } else { mean_secs(&drain) };

    let report = RunReport {
        workload: w.name().into(),
        mode: mode_label(params),
        launcher: exec.base().base_label(),
        wall_secs: wall,
        tasks: out.tasks,
        subdomains: out.width,
        failures_injected: wiring.injector.counters().injected(),
        silent_corruptions: wiring.sdc.count(),
        launch_errors: out.launch_errors,
        kills_applied: kills_applied.len(),
        recovery_latency_secs: recovery,
        detection_latency_secs: mean_secs(&cluster.detection_latency_secs()),
        tasks_reexecuted: cluster_reexecuted(&localities, out.tasks),
        snapshots: exec.snapshots().counts(),
        localities,
        final_checksum: checksum_of(&out.finals),
    };
    Ok((gather(&out.finals), report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    fn clustered(spec: &str) -> RunParams {
        RunParams {
            cluster: Some(ClusterSpec::parse(spec).unwrap()),
            ..RunParams::default()
        }
    }

    #[test]
    fn plain_pool_run_is_deterministic_and_survives() {
        let rt = rt();
        let w = by_name("forkjoin", 1.0).unwrap();
        let (out_a, rep_a) = run(&rt, w.as_ref(), &RunParams::default()).unwrap();
        let (out_b, rep_b) = run(&rt, w.as_ref(), &RunParams::default()).unwrap();
        assert_eq!(out_a, out_b, "pure bodies must be bit-deterministic");
        assert_eq!(rep_a.final_checksum.to_bits(), rep_b.final_checksum.to_bits());
        assert_eq!(rep_a.survival_rate(), 1.0);
        assert_eq!(rep_a.launch_errors, 0);
        assert_eq!(rep_a.tasks_reexecuted, 0);
        assert_eq!(rep_a.mode, "pure_dataflow");
        assert!(rep_a.launcher.starts_with("pool("), "launcher = {}", rep_a.launcher);
        assert_eq!(rep_a.workload, "forkjoin");
        assert!(rep_a.tasks > 16);
    }

    #[test]
    fn cluster_kill_with_replay_matches_pool_checksum() {
        let rt = rt();
        let w = by_name("jacobi", 1.0).unwrap();
        let (pool_out, pool_rep) = run(&rt, w.as_ref(), &RunParams::default()).unwrap();

        let mut params = clustered("4:kill=10@2");
        params.resilience = Some(PolicySpec::Replay { n: 3 });
        let (out, rep) = run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(rep.kills_applied, 1);
        assert_eq!(rep.survival_rate(), 1.0);
        assert!(rep.tasks_reexecuted > 0, "the kill must have cost retries");
        assert!(rep.recovery_latency_secs.is_some());
        assert_eq!(rep.launcher, "cluster(4)");
        assert_eq!(out, pool_out, "recovered run must be bit-identical");
        assert_eq!(rep.final_checksum.to_bits(), pool_rep.final_checksum.to_bits());
    }

    #[test]
    fn cluster_kill_without_resilience_poisons_slots() {
        let rt = rt();
        let w = by_name("stencil1d", 1.0).unwrap();
        let (_, rep) = run(&rt, w.as_ref(), &clustered("4:kill=10@2")).unwrap();
        assert_eq!(rep.kills_applied, 1);
        assert!(rep.launch_errors > 0, "an unprotected kill must poison the DAG");
        assert!(rep.survival_rate() < 1.0);
    }

    #[test]
    fn checkpoint_pool_route_snapshots_and_matches_plain_checksum() {
        let rt = rt();
        let w = by_name("stream", 1.0).unwrap();
        let (plain_out, _) = run(&rt, w.as_ref(), &RunParams::default()).unwrap();
        let params = RunParams {
            resilience: Some(PolicySpec::Checkpoint {
                every: 1,
                backend: SnapshotBackend::Auto,
            }),
            ..RunParams::default()
        };
        let (out, rep) = run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(out, plain_out);
        assert_eq!(rep.launch_errors, 0);
        assert!(rep.snapshots.saved > 0, "snapshot layers must persist");
        assert_eq!(rep.mode, "exec_checkpoint(1)");
    }

    #[test]
    fn checkpoint_cluster_kill_recovers_bit_identical() {
        let rt = rt();
        let w = by_name("stencil2d", 1.0).unwrap();
        let (pool_out, _) = run(&rt, w.as_ref(), &RunParams::default()).unwrap();
        let mut params = clustered("4:kill=12@1");
        params.resilience = Some(PolicySpec::Checkpoint {
            every: 1,
            backend: SnapshotBackend::Auto,
        });
        let (out, rep) = run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(rep.kills_applied, 1);
        assert_eq!(rep.survival_rate(), 1.0, "launch_errors = {}", rep.launch_errors);
        assert_eq!(out, pool_out, "checkpoint repair must restore exact bytes");
        assert!(rep.snapshots.saved > 0);
    }

    #[test]
    fn checkpoint_agas_backend_requires_cluster() {
        let rt = rt();
        let w = by_name("stencil1d", 1.0).unwrap();
        let params = RunParams {
            resilience: Some(PolicySpec::Checkpoint {
                every: 1,
                backend: SnapshotBackend::Agas,
            }),
            ..RunParams::default()
        };
        assert!(run(&rt, w.as_ref(), &params).is_err());
    }

    #[test]
    fn sdc_leaks_without_validation_and_is_caught_with_it() {
        let rt = rt();
        let w = by_name("forkjoin", 1.0).unwrap();
        let (clean_out, clean_rep) = run(&rt, w.as_ref(), &RunParams::default()).unwrap();

        // Control arm: corruption flows through undetected.
        let leaky = RunParams {
            sdc_rate: Some(0.5),
            validate: false,
            ..RunParams::default()
        };
        let (bad_out, bad_rep) = run(&rt, w.as_ref(), &leaky).unwrap();
        assert!(bad_rep.silent_corruptions > 0, "0.5/task over many tasks must land");
        assert_eq!(bad_rep.launch_errors, 0, "silent means silent: nothing failed");
        assert_ne!(bad_out, clean_out, "undetected corruption must reach the output");

        // Detection arm: validation + replay recover the exact result.
        let guarded = RunParams {
            sdc_rate: Some(0.2),
            resilience: Some(PolicySpec::Replay { n: 10 }),
            ..RunParams::default()
        };
        let (good_out, good_rep) = run(&rt, w.as_ref(), &guarded).unwrap();
        assert!(good_rep.silent_corruptions > 0);
        assert_eq!(good_rep.launch_errors, 0);
        assert!(good_rep.tasks_reexecuted > 0, "caught corruptions cost retries");
        assert_eq!(good_out, clean_out, "validated replay must restore exact bytes");
        assert_eq!(
            good_rep.final_checksum.to_bits(),
            clean_rep.final_checksum.to_bits()
        );
    }
}
