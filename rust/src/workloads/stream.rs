//! A streaming pipeline with sustained ingest: every layer, slot 0
//! *generates* a fresh item (a sourceless task — empty dependency list,
//! the shape no other zoo member has), slots 1–3 each transform the
//! item the upstream stage produced last layer (systolic: an item
//! ingested at layer `t` leaves stage 3 at layer `t+3`), and slot 4
//! folds finished items into a running exponentially-weighted
//! accumulator. This is arXiv 1710.09074's stream-processing pattern:
//! failures don't dilate across a spatial domain, they ride the
//! pipeline — a killed stage loses exactly the items in flight, and the
//! accumulator's history makes that loss *stateful* (which is what the
//! checkpoint strategy has to protect).

use std::f64::consts::TAU;

use crate::stencil::Chunk;

use super::{TaskSpec, Workload};

/// Points per stream item.
const ITEM_LEN: usize = 16;
/// Transform stages between ingest and the accumulator.
const STAGES: usize = 3;
/// Accumulator decay: `acc' = DECAY·acc + mean(item)`.
const DECAY: f64 = 0.9;

pub struct Stream {
    /// Layers = items ingested (the pipeline runs one beat per layer).
    beats: usize,
    window: usize,
}

impl Stream {
    /// Scale stretches the beat count; the pipeline depth stays fixed.
    pub fn scaled(scale: f64) -> Self {
        Stream { beats: ((12.0 * scale).round() as usize).max(4), window: 4 }
    }

    /// The deterministic ingest source for beat `t`.
    fn source(&self, t: usize) -> Vec<f64> {
        let total = (self.beats * ITEM_LEN) as f64;
        (0..ITEM_LEN)
            .map(|i| (TAU * (t * ITEM_LEN + i) as f64 / total).sin())
            .collect()
    }
}

impl Workload for Stream {
    fn name(&self) -> &'static str {
        "stream"
    }

    fn describe(&self) -> &'static str {
        "streaming pipeline with sustained ingest"
    }

    /// Slots 0..=STAGES hold the (empty, all-zero) pipeline registers;
    /// the last slot is the accumulator.
    fn initial(&self) -> Vec<Chunk> {
        let mut slots: Vec<Chunk> =
            (0..=STAGES).map(|_| Chunk::new(vec![0.0; ITEM_LEN])).collect();
        slots.push(Chunk::new(vec![0.0]));
        slots
    }

    fn layers(&self) -> usize {
        self.beats
    }

    fn layer_tasks(&self, layer: usize) -> Vec<TaskSpec> {
        let mut specs = Vec::with_capacity(STAGES + 2);
        // Ingest: no dependencies at all — the task is ready the moment
        // it is launched, beat after beat.
        let item = self.source(layer);
        specs.push(TaskSpec::new(Vec::new(), move |_: &[Chunk]| Ok(item.clone())));
        // Transform stages: each consumes what the upstream stage
        // produced last beat. Bounded maps, so the stream can run
        // indefinitely without blowing up.
        specs.push(TaskSpec::new(vec![0], |v: &[Chunk]| {
            Ok(v[0].data.iter().map(|x| 0.5 * x + 0.1).collect())
        }));
        specs.push(TaskSpec::new(vec![1], |v: &[Chunk]| {
            Ok(v[0].data.iter().map(|x| x * x - 0.3).collect())
        }));
        specs.push(TaskSpec::new(vec![2], |v: &[Chunk]| {
            Ok(v[0].data.iter().map(|x| x.sin()).collect())
        }));
        // Accumulator: fold the item leaving the pipeline into the
        // running state — the stream's only long-lived value.
        specs.push(TaskSpec::new(vec![STAGES, STAGES + 1], |v: &[Chunk]| {
            let (item, acc) = (&v[0], &v[1]);
            let mean = item.data.iter().sum::<f64>() / item.data.len() as f64;
            Ok(vec![DECAY * acc.data[0] + mean])
        }));
        specs
    }

    fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime_handle::Runtime;
    use crate::workloads::{engine, RunParams};

    #[test]
    fn pipeline_shape_has_sourceless_ingest_and_stateful_tail() {
        let w = Stream::scaled(1.0);
        assert_eq!(w.initial().len(), 5);
        let specs = w.layer_tasks(0);
        assert_eq!(specs.len(), 5);
        assert!(specs[0].deps.is_empty(), "ingest must be sourceless");
        assert_eq!(specs[4].deps, vec![3, 4], "accumulator folds item + own state");
    }

    #[test]
    fn sustained_ingest_stays_bounded_and_deterministic() {
        let rt = Runtime::builder().workers(2).build();
        let w = Stream::scaled(1.0);
        let (out_a, rep) = engine::run(&rt, &w, &RunParams::default()).unwrap();
        let (out_b, _) = engine::run(&rt, &w, &RunParams::default()).unwrap();
        assert_eq!(out_a, out_b, "same beats, same bytes");
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.subdomains, 5);
        assert_eq!(rep.tasks, 12 * 5);
        assert_eq!(out_a.len(), 4 * ITEM_LEN + 1);
        // Stage 3 is sin(·), so items leave the pipeline in [-1, 1]; the
        // geometric fold then bounds the accumulator by 1/(1-DECAY).
        let acc = out_a[4 * ITEM_LEN];
        assert!(acc.is_finite() && acc.abs() < 1.0 / (1.0 - DECAY), "acc = {acc}");
        // The accumulator must actually have accumulated something.
        assert_ne!(acc, 0.0);
    }
}
