//! The workload zoo: structurally distinct computation patterns behind
//! one [`Workload`] trait, all runnable through the full
//! `--resilience` × `--cluster` fault-model matrix by the generic
//! [`engine`].
//!
//! The resilience-design-patterns literature (arXiv 1611.02717,
//! 1710.09074) argues a resilience mechanism is only understood once it
//! is evaluated against structurally different DAG shapes — the repo's
//! original §V-B 1D stencil is exactly one shape. This module supplies
//! the missing ones:
//!
//! | workload | DAG shape | what it stresses |
//! |---|---|---|
//! | [`stencil1d`] | ring of width-3 dependency cones | the §V-B baseline, now engine-hosted |
//! | [`stencil2d`] | 2D torus, width-5 cones | failure cones overlapping in two dimensions |
//! | [`forkjoin`] | recursive fork/leaf/join tree | replay cost compounding up the tree |
//! | [`jacobi`] | smoothing + per-step global reduction | `when_all` at width = domain size |
//! | [`stream`] | systolic pipeline with sustained ingest | sourceless tasks (empty deps), long chains |
//!
//! Every workload expresses its computation as *layers* of
//! [`TaskSpec`]s — pure math bodies plus dependency indices into the
//! previous wavefront. The engine owns everything else: fault
//! injection ([`crate::failure::FaultInjector`]), silent-data
//! corruption ([`crate::failure::SdcInjector`]), checksum validation,
//! executor-decorator routing, cluster placement, kill schedules,
//! window barriers, checkpoint/repair, and uniform reporting
//! ([`RunReport`]: survival rate, recovery latency, `tasks_reexecuted`).
//!
//! See `docs/ARCHITECTURE.md` § "Writing a new workload" for the trait
//! contract and how to register a new shape.

pub mod engine;
pub mod forkjoin;
pub mod jacobi;
pub mod stencil1d;
pub mod stencil2d;
pub mod stream;

use std::sync::Arc;

use crate::error::TaskResult;
use crate::stencil::Chunk;

pub use engine::{run, RunParams, RunReport};

/// A pure task body: dependency chunks in (in the declared order),
/// raw output values out. The engine wraps it with the fault wiring —
/// injector draw, checksum attachment, silent corruption, run counting —
/// so workload math stays fault-agnostic and trivially re-runnable.
pub type TaskBody = Arc<dyn Fn(&[Chunk]) -> TaskResult<Vec<f64>> + Send + Sync>;

/// One task of one layer: which slots of the *previous* wavefront it
/// consumes, and the math it runs over them.
#[derive(Clone)]
pub struct TaskSpec {
    /// Indices into the previous wavefront (layer − 1's output slots;
    /// for layer 0, into [`Workload::initial`]). May be empty — a
    /// sourceless task (e.g. pipeline ingest) launches immediately.
    pub deps: Vec<usize>,
    pub body: TaskBody,
}

impl TaskSpec {
    pub fn new(
        deps: Vec<usize>,
        body: impl Fn(&[Chunk]) -> TaskResult<Vec<f64>> + Send + Sync + 'static,
    ) -> Self {
        TaskSpec { deps, body: Arc::new(body) }
    }
}

/// A computation pattern the engine can run resiliently.
///
/// The contract:
/// * the DAG is layered — [`Workload::layer_tasks`]`(t)` declares the
///   tasks of wavefront `t`, whose `deps` index into wavefront `t − 1`
///   (or [`Workload::initial`] for `t = 0`); widths may vary per layer;
/// * bodies are **pure** and deterministic — same deps in, same bytes
///   out, in a fixed operation order — which is what makes a recovered
///   run bit-identical to a fault-free one, on any substrate;
/// * [`Workload::window`] is the repair granularity: the engine
///   barriers every `window` layers, which bounds in-flight work, takes
///   checkpoint snapshots (`checkpoint:K` snapshots every K windows),
///   and scopes the checkpoint repair pass.
pub trait Workload: Send + Sync {
    /// Registry name (also the CLI's `rhpx run <name>`).
    fn name(&self) -> &'static str;
    /// One-line description for listings.
    fn describe(&self) -> &'static str;
    /// The initial wavefront (layer −1's output).
    fn initial(&self) -> Vec<Chunk>;
    /// Number of layers.
    fn layers(&self) -> usize;
    /// The tasks of layer `layer` (0-based), in slot order.
    fn layer_tasks(&self, layer: usize) -> Vec<TaskSpec>;
    /// Repair granularity: barrier (and checkpoint-cadence unit) every
    /// this many layers. Must be ≥ 1.
    fn window(&self) -> usize;
    /// Checksum-validation tolerance.
    fn tol(&self) -> f64 {
        1e-6
    }
}

/// The registry: name → description, one row per workload, shared by
/// `rhpx run --list`, the `table_zoo` bench, and the acceptance matrix
/// so they cannot drift.
pub const WORKLOADS: &[(&str, &str)] = &[
    ("stencil1d", "1D Lax-Wendroff ring stencil (the §V-B DAG, engine-hosted)"),
    ("stencil2d", "2D periodic diffusion stencil (failure cones overlap in two dimensions)"),
    ("forkjoin", "recursive fork-join tree (replay cost compounds up the tree)"),
    ("jacobi", "Jacobi smoothing with per-step global residual reduction"),
    ("stream", "streaming pipeline with sustained ingest"),
];

/// Construct a workload by registry name. `scale` stretches the layer
/// count (1.0 = the test-size geometry every acceptance test runs);
/// widths stay fixed so the DAG shape is scale-invariant.
pub fn by_name(name: &str, scale: f64) -> Option<Box<dyn Workload>> {
    match name {
        "stencil1d" => Some(Box::new(stencil1d::Stencil1d::scaled(scale))),
        "stencil2d" => Some(Box::new(stencil2d::Stencil2d::scaled(scale))),
        "forkjoin" => Some(Box::new(forkjoin::ForkJoin::scaled(scale))),
        "jacobi" => Some(Box::new(jacobi::Jacobi::scaled(scale))),
        "stream" => Some(Box::new(stream::Stream::scaled(scale))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_listed_workload() {
        for (name, _) in WORKLOADS {
            let w = by_name(name, 1.0).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(w.name(), *name);
            assert!(!w.describe().is_empty());
            assert!(w.layers() >= 2, "{name}: too few layers");
            assert!(w.window() >= 1, "{name}: window must be >= 1");
            assert!(!w.initial().is_empty(), "{name}: empty initial wavefront");
        }
        assert!(by_name("bogus", 1.0).is_none());
    }

    #[test]
    fn every_workload_declares_well_formed_layers() {
        for (name, _) in WORKLOADS {
            let w = by_name(name, 1.0).unwrap();
            let mut prev_width = w.initial().len();
            let mut total = 0usize;
            for layer in 0..w.layers() {
                let specs = w.layer_tasks(layer);
                assert!(!specs.is_empty(), "{name}: empty layer {layer}");
                for (j, s) in specs.iter().enumerate() {
                    for &d in &s.deps {
                        assert!(
                            d < prev_width,
                            "{name}: layer {layer} slot {j} dep {d} out of range {prev_width}"
                        );
                    }
                }
                total += specs.len();
                prev_width = specs.len();
            }
            // Enough tasks for the acceptance kill schedule (kill=10@2).
            assert!(total > 16, "{name}: only {total} tasks");
        }
    }
}
