//! Jacobi smoothing with a per-step global reduction: every layer runs
//! one weighted-smoothing task per subdomain (a width-3 ring, like the
//! 1D stencil) *plus* one residual task whose dependency list is the
//! entire domain — a `when_all` at width = domain size, the paper's
//! global-reduction shape. That reduction task is the interesting
//! failure target: it sits downstream of *every* subdomain, so a kill
//! anywhere in the layer poisons it, and a kill of the reduction itself
//! must not take the domain down with it.
//!
//! The smoother is the periodic three-point kernel
//! `out[i] = ¼·u[i−1] + ½·u[i] + ¼·u[i+1]` (weights sum to 1, so the
//! global sum over value slots is conserved — pinned by the unit test);
//! the residual is the L1 norm of the whole domain.

use crate::error::TaskResult;
use crate::stencil::{Chunk, Domain};

use super::{TaskSpec, Workload};

pub struct Jacobi {
    /// Value subdomains (the wavefront also carries one residual slot).
    n_sub: usize,
    nx: usize,
    layers: usize,
    window: usize,
}

impl Jacobi {
    /// Scale stretches the layer count; the domain width (and with it
    /// the reduction's fan-in) stays fixed.
    pub fn scaled(scale: f64) -> Self {
        Jacobi {
            n_sub: 8,
            nx: 32,
            layers: ((8.0 * scale).round() as usize).max(2),
            window: 4,
        }
    }

    /// Periodic three-point smoothing over one ghost cell per side.
    fn smooth(v: &[Chunk]) -> TaskResult<Vec<f64>> {
        let (left, center, right) = (&v[0], &v[1], &v[2]);
        let n = center.data.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let lo = if i == 0 { left.data[left.data.len() - 1] } else { center.data[i - 1] };
            let hi = if i + 1 == n { right.data[0] } else { center.data[i + 1] };
            out.push(0.25 * lo + 0.5 * center.data[i] + 0.25 * hi);
        }
        Ok(out)
    }
}

impl Workload for Jacobi {
    fn name(&self) -> &'static str {
        "jacobi"
    }

    fn describe(&self) -> &'static str {
        "Jacobi smoothing with per-step global residual reduction"
    }

    /// Value slots 0..n_sub, plus slot n_sub holding the (initially
    /// zero) residual.
    fn initial(&self) -> Vec<Chunk> {
        let mut slots = Domain::sine(self.n_sub, self.nx).subdomains;
        slots.push(Chunk::new(vec![0.0]));
        slots
    }

    fn layers(&self) -> usize {
        self.layers
    }

    fn layer_tasks(&self, _layer: usize) -> Vec<TaskSpec> {
        let n = self.n_sub;
        let mut specs: Vec<TaskSpec> = (0..n)
            .map(|j| {
                TaskSpec::new(vec![(j + n - 1) % n, j, (j + 1) % n], Self::smooth)
            })
            .collect();
        // The global reduction: depends on every value slot of the
        // previous wavefront at once (`when_all` at domain width).
        specs.push(TaskSpec::new((0..n).collect(), |v: &[Chunk]| {
            Ok(vec![v
                .iter()
                .map(|c| c.data.iter().map(|x| x.abs()).sum::<f64>())
                .sum::<f64>()])
        }));
        specs
    }

    fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime_handle::Runtime;
    use crate::workloads::{engine, RunParams};

    #[test]
    fn wavefront_carries_values_plus_one_reduction_slot() {
        let w = Jacobi::scaled(1.0);
        assert_eq!(w.initial().len(), 9);
        let specs = w.layer_tasks(0);
        assert_eq!(specs.len(), 9);
        assert_eq!(specs[8].deps, (0..8).collect::<Vec<_>>(), "width-8 when_all");
    }

    #[test]
    fn smoothing_conserves_the_sum_and_residual_tracks_the_norm() {
        let rt = Runtime::builder().workers(2).build();
        let w = Jacobi::scaled(1.0);
        let initial_sum: f64 = Domain::sine(8, 32).gather().iter().sum();
        let (out, rep) = engine::run(&rt, &w, &RunParams::default()).unwrap();
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.subdomains, 9);
        assert_eq!(out.len(), 8 * 32 + 1);
        let (values, residual) = out.split_at(8 * 32);
        let final_sum: f64 = values.iter().sum();
        assert!(
            (final_sum - initial_sum).abs() < 1e-9,
            "smoothing weights sum to 1: {initial_sum} -> {final_sum}"
        );
        // The final residual is the L1 norm of the *previous* layer's
        // values — nonzero and no larger than the initial norm (the
        // smoother is a contraction in L1 for this sign-alternating
        // profile).
        let initial_l1: f64 = Domain::sine(8, 32).gather().iter().map(|x| x.abs()).sum();
        assert!(residual[0] > 0.0);
        assert!(residual[0] <= initial_l1 + 1e-9, "{} > {initial_l1}", residual[0]);
    }
}
