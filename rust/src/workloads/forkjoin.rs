//! A recursive fork-join tree: each round forks a root vector three
//! levels deep (widths 2 → 4 → 8), applies a leaf kernel to the eight
//! fragments, then joins back up (8 → 4 → 2 → 1), and the joined root
//! feeds the next round. This is arXiv 1710.09074's divide-and-conquer
//! pattern — the shape the 1D ring can't express: a failure near the
//! root of the join half poisons *everything* above it, so replay cost
//! compounds up the tree instead of dilating sideways.
//!
//! The leaf kernel is `sin(x)` elementwise (a contraction, so repeated
//! rounds stay bounded); forks split exact halves and joins concatenate
//! them back, so fork/join layers are pure data movement and the final
//! root has the same length as the input.

use crate::error::TaskResult;
use crate::stencil::Chunk;

use super::{TaskSpec, Workload};

/// Fork depth: 2^3 = 8 leaves per round.
const DEPTH: u32 = 3;
/// Root vector length (divisible by 2^DEPTH).
const ROOT_LEN: usize = 64;
/// Layers per round: DEPTH forks + 1 leaf + DEPTH joins.
const LAYERS_PER_ROUND: usize = 2 * DEPTH as usize + 1;

pub struct ForkJoin {
    rounds: usize,
}

impl ForkJoin {
    /// Scale stretches the round count; the tree depth stays fixed so
    /// the fan-out/fan-in shape is scale-invariant.
    pub fn scaled(scale: f64) -> Self {
        ForkJoin { rounds: ((3.0 * scale).round() as usize).max(1) }
    }

    /// Fork task: take the first or second half of the single parent.
    fn fork(parent: usize, second_half: bool) -> TaskSpec {
        TaskSpec::new(vec![parent], move |v: &[Chunk]| {
            let data = &v[0].data;
            let half = data.len() / 2;
            Ok(if second_half { data[half..].to_vec() } else { data[..half].to_vec() })
        })
    }

    /// Join task: concatenate two siblings back into their parent.
    fn join(lhs: usize, rhs: usize) -> TaskSpec {
        TaskSpec::new(vec![lhs, rhs], |v: &[Chunk]| {
            let mut out = Vec::with_capacity(v[0].data.len() + v[1].data.len());
            out.extend_from_slice(&v[0].data);
            out.extend_from_slice(&v[1].data);
            Ok(out)
        })
    }

    /// Leaf kernel on one fragment.
    fn leaf(slot: usize) -> TaskSpec {
        TaskSpec::new(vec![slot], |v: &[Chunk]| {
            Ok(v[0].data.iter().map(|x| x.sin()).collect())
        })
    }
}

impl Workload for ForkJoin {
    fn name(&self) -> &'static str {
        "forkjoin"
    }

    fn describe(&self) -> &'static str {
        "recursive fork-join tree (replay cost compounds up the tree)"
    }

    fn initial(&self) -> Vec<Chunk> {
        let data = (0..ROOT_LEN)
            .map(|i| (std::f64::consts::TAU * i as f64 / ROOT_LEN as f64).sin())
            .collect();
        vec![Chunk::new(data)]
    }

    fn layers(&self) -> usize {
        self.rounds * LAYERS_PER_ROUND
    }

    fn layer_tasks(&self, layer: usize) -> Vec<TaskSpec> {
        let depth = DEPTH as usize;
        match layer % LAYERS_PER_ROUND {
            // Fork levels: width doubles each layer (2, 4, 8, …); task j
            // splits parent j/2, taking the half its parity selects.
            l if l < depth => {
                let width = 2 << l;
                (0..width).map(|j| Self::fork(j / 2, j % 2 == 1)).collect()
            }
            // Leaf level: one kernel task per fragment.
            l if l == depth => (0..1 << depth).map(Self::leaf).collect(),
            // Join levels: width halves each layer (4, 2, 1, … after the
            // 8-wide leaf level); task j rejoins siblings 2j and 2j+1.
            l => {
                let width = 1 << (2 * depth - l);
                (0..width).map(|j| Self::join(2 * j, 2 * j + 1)).collect()
            }
        }
    }

    /// One full round per repair window, so a checkpoint layer always
    /// lands on the joined root (width 1) — the natural cut point of the
    /// tree.
    fn window(&self) -> usize {
        LAYERS_PER_ROUND
    }

    fn tol(&self) -> f64 {
        1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime_handle::Runtime;
    use crate::workloads::{engine, RunParams};

    #[test]
    fn tree_shape_is_fork_leaf_join() {
        let w = ForkJoin::scaled(1.0);
        let widths: Vec<usize> =
            (0..LAYERS_PER_ROUND).map(|l| w.layer_tasks(l).len()).collect();
        assert_eq!(widths, vec![2, 4, 8, 8, 4, 2, 1]);
        assert_eq!(w.layers(), 21);
    }

    #[test]
    fn rounds_preserve_length_and_contract_into_sin_range() {
        let rt = Runtime::builder().workers(2).build();
        let w = ForkJoin::scaled(1.0);
        let (out, rep) = engine::run(&rt, &w, &RunParams::default()).unwrap();
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.subdomains, 1, "the tree must join back to one root");
        assert_eq!(out.len(), ROOT_LEN, "fork/join must preserve the root length");
        // After ≥1 round every element went through sin at least once.
        assert!(out.iter().all(|x| x.abs() <= 1.0));
        // And the kernel actually ran: sin is not the identity.
        let fresh: Vec<f64> = w.initial()[0].data.to_vec();
        assert_ne!(out, fresh);
        // Reference: the whole tree is equivalent to rounds× elementwise
        // sin over the root vector.
        let mut expect = fresh;
        for _ in 0..3 {
            expect = expect.iter().map(|x| x.sin()).collect();
        }
        assert_eq!(out, expect, "tree must equal rounds of elementwise sin");
    }
}
