//! A 2D periodic diffusion stencil: a `gx × gy` torus of tiles, each
//! task depending on its own tile and all four neighbors. Where the 1D
//! ring gives a failure a width-3 cone per layer, here the cone dilates
//! in two dimensions (width 5 per layer) — a locality kill poisons a
//! 2D diamond of tiles, which is the shape the repair pass has to chase.
//!
//! The kernel is an explicit 5-point diffusion step
//! `out = c + k·(n + s + e + w − 4c)` with ghost rows/columns exchanged
//! through the dependency edges, exactly like the 1D driver exchanges
//! ghost cells. With periodic boundaries the step conserves the global
//! sum, which the unit test pins.

use std::f64::consts::TAU;

use crate::error::TaskResult;
use crate::stencil::Chunk;

use super::{TaskSpec, Workload};

/// Diffusion coefficient of the 5-point step; k < 0.25 keeps the
/// explicit scheme stable.
const K_DIFF: f64 = 0.2;

pub struct Stencil2d {
    /// Tiles per side (the grid is `gx × gy`, periodic both ways).
    gx: usize,
    gy: usize,
    /// Points per tile side (tiles are `tx × ty`).
    tx: usize,
    ty: usize,
    layers: usize,
    window: usize,
}

impl Stencil2d {
    /// Scale stretches the layer count; the 3 × 3 tile grid stays fixed
    /// so the two-dimensional dependency cone is scale-invariant.
    pub fn scaled(scale: f64) -> Self {
        Stencil2d {
            gx: 3,
            gy: 3,
            tx: 8,
            ty: 8,
            layers: ((8.0 * scale).round() as usize).max(2),
            window: 4,
        }
    }

    /// The 5-point diffusion body for tile `(x, y)`: assemble the
    /// ghost-extended `(ty+2) × (tx+2)` tile from the center and the
    /// facing edges of the four neighbors (corners stay zero — the
    /// 5-point star never reads them), then take one step.
    fn step(v: &[Chunk], tx: usize, ty: usize) -> TaskResult<Vec<f64>> {
        let (center, left, right, up, down) = (&v[0], &v[1], &v[2], &v[3], &v[4]);
        let ex = tx + 2;
        let mut ext = vec![0.0; (ty + 2) * ex];
        for r in 0..ty {
            for c in 0..tx {
                ext[(r + 1) * ex + (c + 1)] = center.data[r * tx + c];
            }
            // Periodic ghosts: my left ghost column is the left
            // neighbor's rightmost column, and so on around.
            ext[(r + 1) * ex] = left.data[r * tx + (tx - 1)];
            ext[(r + 1) * ex + (tx + 1)] = right.data[r * tx];
        }
        for c in 0..tx {
            ext[c + 1] = up.data[(ty - 1) * tx + c];
            ext[(ty + 1) * ex + (c + 1)] = down.data[c];
        }
        let mut out = vec![0.0; ty * tx];
        for r in 0..ty {
            for c in 0..tx {
                let mid = ext[(r + 1) * ex + (c + 1)];
                let star = ext[r * ex + (c + 1)]
                    + ext[(r + 2) * ex + (c + 1)]
                    + ext[(r + 1) * ex + c]
                    + ext[(r + 1) * ex + (c + 2)];
                out[r * tx + c] = mid + K_DIFF * (star - 4.0 * mid);
            }
        }
        Ok(out)
    }
}

impl Workload for Stencil2d {
    fn name(&self) -> &'static str {
        "stencil2d"
    }

    fn describe(&self) -> &'static str {
        "2D periodic diffusion stencil (failure cones overlap in two dimensions)"
    }

    fn initial(&self) -> Vec<Chunk> {
        let (gx, gy, tx, ty) = (self.gx, self.gy, self.tx, self.ty);
        let (nx, ny) = ((gx * tx) as f64, (gy * ty) as f64);
        (0..gx * gy)
            .map(|j| {
                let (x, y) = (j % gx, j / gx);
                let data = (0..ty * tx)
                    .map(|i| {
                        let (r, c) = (i / tx, i % tx);
                        let xg = (x * tx + c) as f64;
                        let yg = (y * ty + r) as f64;
                        (TAU * xg / nx).sin() * (TAU * yg / ny).cos()
                    })
                    .collect();
                Chunk::new(data)
            })
            .collect()
    }

    fn layers(&self) -> usize {
        self.layers
    }

    fn layer_tasks(&self, _layer: usize) -> Vec<TaskSpec> {
        let (gx, gy, tx, ty) = (self.gx, self.gy, self.tx, self.ty);
        (0..gx * gy)
            .map(|j| {
                let (x, y) = (j % gx, j / gx);
                let deps = vec![
                    j,                             // center
                    y * gx + (x + gx - 1) % gx,    // left
                    y * gx + (x + 1) % gx,         // right
                    ((y + gy - 1) % gy) * gx + x,  // up
                    ((y + 1) % gy) * gx + x,       // down
                ];
                TaskSpec::new(deps, move |v: &[Chunk]| Self::step(v, tx, ty))
            })
            .collect()
    }

    fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime_handle::Runtime;
    use crate::workloads::{engine, RunParams};

    #[test]
    fn periodic_diffusion_conserves_the_global_sum() {
        let rt = Runtime::builder().workers(2).build();
        let w = Stencil2d::scaled(1.0);
        let initial_sum: f64 =
            w.initial().iter().flat_map(|c| c.data.iter().copied()).sum();
        let (out, rep) = engine::run(&rt, &w, &RunParams::default()).unwrap();
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.subdomains, 9);
        assert_eq!(out.len(), 9 * 64);
        let final_sum: f64 = out.iter().sum();
        // Every cell's neighbors appear exactly four times across the
        // torus, so the diffusion exchange nets to zero each layer.
        assert!(
            (final_sum - initial_sum).abs() < 1e-9,
            "sum drifted: {initial_sum} -> {final_sum}"
        );
        // Diffusion must actually smooth: the field contracts toward its
        // mean, it doesn't sit still.
        let initial_sq: f64 =
            w.initial().iter().flat_map(|c| c.data.iter().map(|v| v * v)).sum();
        let final_sq: f64 = out.iter().map(|v| v * v).sum();
        assert!(final_sq < initial_sq * 0.9, "{initial_sq} -> {final_sq}");
    }
}
