//! The §V-B 1D Lax-Wendroff ring stencil as a [`Workload`] — the same
//! DAG `stencil::driver` builds (one task per subdomain per iteration,
//! depending on itself and both neighbors), expressed through
//! [`TaskSpec`]s so the generic engine hosts it. At scale 1.0 the
//! geometry is exactly [`StencilParams::tiny`]'s
//! (8 × 64, 10 iterations, 4 steps, unit Courant), which is what lets
//! the equivalence test below pin engine output bit-identical to the
//! legacy driver.
//!
//! [`StencilParams::tiny`]: crate::stencil::StencilParams::tiny

use crate::stencil::domain::build_extended;
use crate::stencil::{kernel, Chunk, Domain};

use super::{TaskSpec, Workload};

pub struct Stencil1d {
    n_sub: usize,
    nx: usize,
    iterations: usize,
    /// Time steps advanced per task (= ghost cells per side).
    steps: usize,
    courant: f64,
    window: usize,
}

impl Stencil1d {
    /// Scale stretches the iteration count; the ring width stays 8 so
    /// the DAG shape (and the per-task dependency cone) is invariant.
    pub fn scaled(scale: f64) -> Self {
        Stencil1d {
            n_sub: 8,
            nx: 64,
            iterations: ((10.0 * scale).round() as usize).max(2),
            steps: 4,
            courant: 1.0,
            window: 4,
        }
    }
}

impl Workload for Stencil1d {
    fn name(&self) -> &'static str {
        "stencil1d"
    }

    fn describe(&self) -> &'static str {
        "1D Lax-Wendroff ring stencil (the §V-B DAG, engine-hosted)"
    }

    fn initial(&self) -> Vec<Chunk> {
        Domain::sine(self.n_sub, self.nx).subdomains
    }

    fn layers(&self) -> usize {
        self.iterations
    }

    fn layer_tasks(&self, _layer: usize) -> Vec<TaskSpec> {
        let n = self.n_sub;
        let (steps, courant) = (self.steps, self.courant);
        (0..n)
            .map(|j| {
                TaskSpec::new(
                    vec![(j + n - 1) % n, j, (j + 1) % n],
                    move |v: &[Chunk]| {
                        let ext = build_extended(&v[0], &v[1], &v[2], steps);
                        Ok(kernel::lax_wendroff_multistep_owned(ext, steps, courant))
                    },
                )
            })
            .collect()
    }

    fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime_handle::Runtime;
    use crate::stencil::{self, Mode, StencilParams};
    use crate::workloads::{engine, RunParams};

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn engine_run_matches_legacy_driver_bit_for_bit() {
        let rt = rt();
        let params = StencilParams::tiny(); // 8 × 64, 10 iters, Mode::Pure
        assert_eq!(params.mode, Mode::Pure);
        let (legacy, legacy_rep) = stencil::run(&rt, &params).unwrap();

        let w = Stencil1d::scaled(1.0);
        let (ours, rep) = engine::run(&rt, &w, &RunParams::default()).unwrap();

        assert_eq!(ours, legacy, "engine must reproduce the driver's exact bytes");
        assert_eq!(rep.final_checksum.to_bits(), legacy_rep.final_checksum.to_bits());
        assert_eq!(rep.tasks, params.total_tasks());
        assert_eq!(rep.subdomains, params.n_sub);
    }

    #[test]
    fn unit_courant_is_an_exact_shift() {
        let rt = rt();
        let w = Stencil1d::scaled(1.0);
        let (out, rep) = engine::run(&rt, &w, &RunParams::default()).unwrap();
        assert_eq!(rep.launch_errors, 0);
        // c = 1 Lax-Wendroff advects the profile by exactly one cell per
        // step: 10 iterations × 4 steps = 40 cells.
        let exact = Domain::sine(8, 64).exact_sine_shifted(40.0);
        let max_err = out
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err < 1e-9, "max_err = {max_err}");
    }
}
