//! Work-stealing lightweight task scheduler — the HPX substrate.
//!
//! HPX component (4): "work-stealing lightweight task scheduler that
//! enables finer-grained parallelization and synchronization". This module
//! provides the thread pool the whole crate schedules onto:
//!
//! * one [`WorkQueue`] per worker (LIFO pop / FIFO steal) plus a global
//!   injector queue for submissions from non-worker threads,
//! * condvar-based parking with a lost-wakeup-safe idle protocol,
//! * cooperative helping: a worker blocked on a future runs queued tasks
//!   while it waits (see [`crate::future`]), so `Future::get` inside a
//!   task cannot deadlock the pool.
//!
//! Paper mapping: the substrate under every measurement — Table I/Fig 2
//! overheads are amortized against plain `async_` launches on this pool.

mod queue;
mod worker;

pub use queue::WorkQueue;

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of schedulable work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set for the lifetime of a worker thread: (pool, worker index).
    /// Holds a strong `Arc` — cheaper to read on the spawn hot path than
    /// upgrading a `Weak`; cleared by the worker loop at shutdown, so no
    /// cycle outlives the pool.
    static CURRENT: RefCell<Option<(Arc<Pool>, usize)>> = const { RefCell::new(None) };
}

/// Shared state of the scheduler.
pub struct Pool {
    queues: Vec<Arc<WorkQueue>>,
    injector: WorkQueue,
    sleep_lock: Mutex<()>,
    sleep_cv: Condvar,
    idle: AtomicUsize,
    shutdown: AtomicBool,
    spawned: AtomicU64,
    completed: AtomicU64,
    stolen: AtomicU64,
    idle_lock: Mutex<()>,
    idle_cv: Condvar,
}

impl Pool {
    fn new(workers: usize) -> Arc<Self> {
        Arc::new(Pool {
            queues: (0..workers).map(|_| Arc::new(WorkQueue::new())).collect(),
            injector: WorkQueue::new(),
            sleep_lock: Mutex::new(()),
            sleep_cv: Condvar::new(),
            idle: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            spawned: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
        })
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// Submit a job. From a worker thread the job lands on the local
    /// queue (depth-first execution order, like HPX); otherwise on the
    /// global injector. See the free function [`spawn_on`].
    pub fn spawn_job(self: &Arc<Self>, job: Job) {
        spawn_on(self, job);
    }

    /// True if any queue (local or injector) currently holds work.
    fn has_work(&self) -> bool {
        if !self.injector.is_empty() {
            return true;
        }
        self.queues.iter().any(|q| !q.is_empty())
    }

    /// Wake one parked worker if any are parked.
    fn notify_one(&self) {
        if self.idle.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep_lock.lock().unwrap();
            self.sleep_cv.notify_one();
        }
    }

    fn notify_all(&self) {
        let _g = self.sleep_lock.lock().unwrap();
        self.sleep_cv.notify_all();
    }

    /// Find a job for worker `idx`: local LIFO, then injector, then steal.
    fn find_job(&self, idx: usize, rng_state: &mut u64) -> Option<Job> {
        if let Some(j) = self.queues[idx].pop() {
            return Some(j);
        }
        if let Some(j) = self.injector.steal() {
            return Some(j);
        }
        let n = self.queues.len();
        if n > 1 {
            // Start the steal scan at a pseudo-random victim to avoid
            // convoying on worker 0.
            *rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let start = (*rng_state >> 33) as usize % n;
            for off in 0..n {
                let v = (start + off) % n;
                if v == idx {
                    continue;
                }
                if let Some(j) = self.queues[v].steal() {
                    self.stolen.fetch_add(1, Ordering::Relaxed);
                    return Some(j);
                }
            }
        }
        None
    }

    /// Run a single queued job if one is available. Used both by the
    /// worker loop and by cooperative helping in `Future::get`.
    pub fn try_run_one(self: &Arc<Self>, idx: usize) -> bool {
        let mut rng = 0x9e3779b97f4a7c15u64 ^ (idx as u64);
        if let Some(job) = self.find_job(idx, &mut rng) {
            self.run_job(job);
            true
        } else {
            false
        }
    }

    fn run_job(self: &Arc<Self>, job: Job) {
        job();
        let done = self.completed.fetch_add(1, Ordering::SeqCst) + 1;
        if done == self.spawned.load(Ordering::SeqCst) {
            let _g = self.idle_lock.lock().unwrap();
            self.idle_cv.notify_all();
        }
    }

    /// Block until every spawned job has completed.
    pub fn wait_idle(&self) {
        let mut g = self.idle_lock.lock().unwrap();
        loop {
            if self.completed.load(Ordering::SeqCst) == self.spawned.load(Ordering::SeqCst) {
                return;
            }
            let (ng, _t) = self
                .idle_cv
                .wait_timeout(g, std::time::Duration::from_millis(1))
                .unwrap();
            g = ng;
        }
    }

    /// Scheduler statistics snapshot.
    pub fn stats(&self) -> SchedulerStats {
        SchedulerStats {
            spawned: self.spawned.load(Ordering::SeqCst),
            completed: self.completed.load(Ordering::SeqCst),
            stolen: self.stolen.load(Ordering::Relaxed),
            workers: self.queues.len(),
        }
    }
}

/// Counters exposed by [`Pool::stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerStats {
    pub spawned: u64,
    pub completed: u64,
    pub stolen: u64,
    pub workers: usize,
}

/// Handle that owns the worker threads; dropping it shuts the pool down.
pub struct Scheduler {
    pool: Arc<Pool>,
    handles: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Start a scheduler with `workers` worker threads (min 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let pool = Pool::new(workers);
        let handles = (0..workers)
            .map(|idx| {
                let pool = Arc::clone(&pool);
                std::thread::Builder::new()
                    .name(format!("rhpx-worker-{idx}"))
                    .spawn(move || worker::worker_loop(pool, idx))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Scheduler { pool, handles }
    }

    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    /// Submit a job for execution.
    pub fn spawn(&self, job: Job) {
        spawn_on(&self.pool, job);
    }

    /// Block until all submitted work has completed.
    pub fn wait_idle(&self) {
        self.pool.wait_idle();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.pool.shutdown.store(true, Ordering::SeqCst);
        self.pool.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Drop any jobs that never ran (only possible if the user dropped
        // the scheduler without `wait_idle`); their futures resolve to a
        // broken-promise error via `Promise::drop`.
        for q in &self.pool.queues {
            drop(q.drain());
        }
        drop(self.pool.injector.drain());
    }
}

/// Submit `job` to `pool`, preferring the current worker's local queue.
pub fn spawn_on(pool: &Arc<Pool>, job: Job) {
    pool.spawned.fetch_add(1, Ordering::SeqCst);
    let local = CURRENT.with(|c| {
        c.borrow()
            .as_ref()
            .and_then(|(p, idx)| Arc::ptr_eq(p, pool).then_some(*idx))
    });
    match local {
        Some(idx) => pool.queues[idx].push(job),
        None => pool.injector.push(job),
    }
    pool.notify_one();
}

/// The (pool, worker index) of the current thread, if it is a worker.
pub fn current_worker() -> Option<(Arc<Pool>, usize)> {
    CURRENT.with(|c| c.borrow().as_ref().map(|(p, idx)| (Arc::clone(p), *idx)))
}

pub(crate) fn set_current(pool: &Arc<Pool>, idx: usize) {
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(pool), idx)));
}

pub(crate) fn clear_current() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}
