//! Work-stealing lightweight task scheduler — the HPX substrate.
//!
//! HPX component (4): "work-stealing lightweight task scheduler that
//! enables finer-grained parallelization and synchronization". This module
//! provides the thread pool the whole crate schedules onto:
//!
//! * one lock-free Chase–Lev [`WorkQueue`] per worker (LIFO pop / FIFO
//!   steal) plus a lock-free [`Injector`] for submissions from non-worker
//!   threads (batch-consumed into a worker's local deque),
//! * a wake-counter idle protocol: submitters never take a lock — they
//!   bump an epoch and poke the condvar only when a worker is actually
//!   parked; workers re-check the epoch around parking,
//! * event-driven idle detection: [`Pool::wait_idle`] registers interest
//!   and sleeps on a condvar that job completion notifies only when a
//!   waiter is present and the counts balance — no polling timeout,
//! * cooperative helping: a worker blocked on a future runs queued tasks
//!   while it waits (see [`crate::future`]), so `Future::get` inside a
//!   task cannot deadlock the pool.
//!
//! Every atomic ordering below the default `SeqCst` carries a one-line
//! justification; the remaining `SeqCst` operations implement two Dekker
//! (store-load) patterns — submission vs. worker parking, and completion
//! vs. idle-waiter registration — that genuinely need the total order.
//!
//! Paper mapping: the substrate under every measurement — Table I/Fig 2
//! overheads are amortized against plain `async_` launches on this pool.

mod queue;
mod worker;

pub use queue::{Injector, InjectorBatch, Lineage, LineageLedger, WorkQueue};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of schedulable work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set for the lifetime of a worker thread: (pool, worker index).
    /// Holds a strong `Arc` — cheaper to read on the spawn hot path than
    /// upgrading a `Weak`; cleared by the worker loop at shutdown, so no
    /// cycle outlives the pool.
    static CURRENT: RefCell<Option<(Arc<Pool>, usize)>> = const { RefCell::new(None) };
}

/// Shared state of the scheduler.
pub struct Pool {
    queues: Vec<Arc<WorkQueue>>,
    injector: Injector,
    sleep_lock: Mutex<()>,
    sleep_cv: Condvar,
    /// Workers currently parked (or committing to park) on `sleep_cv`.
    sleepers: AtomicUsize,
    /// Bumped once per submission: a parking worker that observes a bump
    /// since it scanned the queues aborts the park (see `worker_loop`).
    wake_epoch: AtomicU64,
    shutdown: AtomicBool,
    spawned: AtomicU64,
    completed: AtomicU64,
    stolen: AtomicU64,
    idle_lock: Mutex<()>,
    idle_cv: Condvar,
    /// `wait_idle` callers currently registered; completions only touch
    /// `idle_lock` when this is non-zero.
    idle_interest: AtomicUsize,
}

impl Pool {
    fn new(workers: usize) -> Arc<Self> {
        Arc::new(Pool {
            queues: (0..workers).map(|_| Arc::new(WorkQueue::new())).collect(),
            injector: Injector::new(),
            sleep_lock: Mutex::new(()),
            sleep_cv: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            wake_epoch: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            spawned: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            idle_interest: AtomicUsize::new(0),
        })
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// Submit a job. From a worker thread the job lands on the local
    /// queue (depth-first execution order, like HPX); otherwise on the
    /// global injector. See the free function [`spawn_on`].
    pub fn spawn_job(self: &Arc<Self>, job: Job) {
        spawn_on(self, job);
    }

    /// True if any queue (local or injector) currently holds work.
    fn has_work(&self) -> bool {
        if !self.injector.is_empty() {
            return true;
        }
        self.queues.iter().any(|q| !q.is_empty())
    }

    /// Post-submission wake: lock-free. The epoch bump lets a worker that
    /// is *about to* park detect the submission and abort; the condvar
    /// poke (no lock held — allowed, and racing a parking worker is
    /// covered by the epoch re-check plus the bounded timed wait in
    /// `worker_loop`) wakes a worker that is already parked.
    fn notify_one(&self) {
        // SeqCst: Dekker with the parking worker — it increments
        // `sleepers` and *then* scans the queues; we publish the job and
        // *then* read `sleepers`. The total order guarantees at least one
        // side observes the other.
        self.wake_epoch.fetch_add(1, Ordering::SeqCst);
        if self.sleepers.load(Ordering::SeqCst) != 0 {
            self.sleep_cv.notify_one();
        }
    }

    fn notify_all_for_shutdown(&self) {
        // Cold path: take the lock so the wake cannot slip between a
        // worker's shutdown re-check and its wait.
        self.wake_epoch.fetch_add(1, Ordering::SeqCst);
        let _g = self.sleep_lock.lock().unwrap();
        self.sleep_cv.notify_all();
    }

    /// Find a job for worker `idx`: local LIFO, then the injector batch,
    /// then steal. Must only be called on worker `idx`'s own thread (the
    /// owner-side deque contract); `worker_loop` and the guarded
    /// [`Pool::try_run_one`] are the only callers.
    fn find_job(&self, idx: usize, rng_state: &mut u64) -> Option<Job> {
        // SAFETY (all owner-side calls below): this is worker idx's
        // thread, the sole owner of queues[idx].
        if let Some(j) = unsafe { self.queues[idx].pop() } {
            return Some(j);
        }
        // Move every pending external submission into the local deque in
        // one swap; LIFO pop then consumes them in submission order (and
        // other workers can steal the overflow).
        let mut moved = false;
        for job in self.injector.take_all() {
            unsafe { self.queues[idx].push(job) };
            moved = true;
        }
        if moved {
            if let Some(j) = unsafe { self.queues[idx].pop() } {
                return Some(j);
            }
        }
        let n = self.queues.len();
        if n > 1 {
            // Start the steal scan at a pseudo-random victim to avoid
            // convoying on worker 0.
            *rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let start = (*rng_state >> 33) as usize % n;
            for off in 0..n {
                let v = (start + off) % n;
                if v == idx {
                    continue;
                }
                if let Some(j) = self.queues[v].steal() {
                    // Relaxed: statistics only.
                    self.stolen.fetch_add(1, Ordering::Relaxed);
                    crate::trace::emit(crate::trace::EventKind::Steal, v as u64, idx as u64);
                    return Some(j);
                }
            }
        }
        None
    }

    /// Run a single queued job if one is available. Used both by the
    /// worker loop and by cooperative helping in `Future::get`.
    ///
    /// Sound for any caller: the owner-side deque access inside is only
    /// performed when the calling thread actually *is* worker `idx` of
    /// this pool (checked against the thread-local registration);
    /// otherwise this returns `false` without touching the queues.
    pub fn try_run_one(self: &Arc<Self>, idx: usize) -> bool {
        let on_owner_thread = CURRENT.with(|c| {
            matches!(c.borrow().as_ref(), Some((p, i)) if Arc::ptr_eq(p, self) && *i == idx)
        });
        if !on_owner_thread {
            return false;
        }
        let mut rng = 0x9e3779b97f4a7c15u64 ^ (idx as u64);
        if let Some(job) = self.find_job(idx, &mut rng) {
            self.run_job(job);
            true
        } else {
            false
        }
    }

    fn run_job(self: &Arc<Self>, job: Job) {
        // Exec span: a fresh id ties the begin/end pair even when the
        // job migrated queues; the id RMW is skipped entirely when the
        // flight recorder is off (one relaxed load + branch).
        let exec_id = if crate::trace::active() {
            let id = EXEC_SEQ.fetch_add(1, Ordering::Relaxed) + 1;
            crate::trace::emit(crate::trace::EventKind::ExecBegin, id, 0);
            id
        } else {
            0
        };
        job();
        if exec_id != 0 {
            crate::trace::emit(crate::trace::EventKind::ExecEnd, exec_id, 0);
        }
        // SeqCst RMW: (a) Dekker with `wait_idle`'s interest registration
        // (we bump `completed` then read `idle_interest`; the waiter
        // registers interest then reads `completed`), and (b) each
        // completion synchronizes with every earlier completion's release
        // sequence, so whoever observes `completed == spawned` also
        // observes every spawn increment (spawns happen-before the
        // completion of the job they belong to).
        let done = self.completed.fetch_add(1, Ordering::SeqCst) + 1;
        if self.idle_interest.load(Ordering::SeqCst) != 0
            // Relaxed: can only under-read `spawned` relative to *other*
            // threads' in-flight spawns, making the equality a false
            // negative (no notify) — and those spawns' own completions
            // will re-run this check.
            && done == self.spawned.load(Ordering::Relaxed)
        {
            let _g = self.idle_lock.lock().unwrap();
            self.idle_cv.notify_all();
        }
    }

    /// True when every job spawned so far has completed. Reading
    /// `completed` first is deliberate: a stale `spawned` read can only
    /// overshoot via concurrent spawners (an inherent caller race), never
    /// report idle while tracked work is in flight — sub-spawns inside a
    /// running job happen-before that job's completion increment.
    fn all_done(&self) -> bool {
        // SeqCst: synchronizes with the completion RMWs so the subsequent
        // `spawned` read (Relaxed suffices, see above) is current.
        let done = self.completed.load(Ordering::SeqCst);
        done == self.spawned.load(Ordering::Relaxed)
    }

    /// Block until every spawned job has completed. Event-driven: no
    /// polling — job completion notifies `idle_cv` when (and only when) a
    /// waiter is registered and the counts balance.
    pub fn wait_idle(&self) {
        if self.all_done() {
            return;
        }
        let mut g = self.idle_lock.lock().unwrap();
        // SeqCst: Dekker with `run_job` (see there). Registered *under*
        // the lock, so a completion that observes our interest serializes
        // its notify against our wait.
        self.idle_interest.fetch_add(1, Ordering::SeqCst);
        while !self.all_done() {
            g = self.idle_cv.wait(g).unwrap();
        }
        self.idle_interest.fetch_sub(1, Ordering::SeqCst);
    }

    /// Scheduler statistics snapshot.
    pub fn stats(&self) -> SchedulerStats {
        SchedulerStats {
            // Acquire-free snapshot: counters are monotonic and advisory.
            spawned: self.spawned.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
            workers: self.queues.len(),
        }
    }
}

/// Counters exposed by [`Pool::stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerStats {
    pub spawned: u64,
    pub completed: u64,
    pub stolen: u64,
    pub workers: usize,
}

/// Handle that owns the worker threads; dropping it shuts the pool down.
pub struct Scheduler {
    pool: Arc<Pool>,
    handles: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Start a scheduler with `workers` worker threads (min 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let pool = Pool::new(workers);
        let handles = (0..workers)
            .map(|idx| {
                let pool = Arc::clone(&pool);
                std::thread::Builder::new()
                    .name(format!("rhpx-worker-{idx}"))
                    .spawn(move || worker::worker_loop(pool, idx))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Scheduler { pool, handles }
    }

    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    /// Submit a job for execution.
    pub fn spawn(&self, job: Job) {
        spawn_on(&self.pool, job);
    }

    /// Block until all submitted work has completed.
    pub fn wait_idle(&self) {
        self.pool.wait_idle();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        // Release not required: `notify_all_for_shutdown`'s SeqCst epoch
        // bump orders the flag for parked workers; running workers load
        // it with Acquire.
        self.pool.shutdown.store(true, Ordering::SeqCst);
        self.pool.notify_all_for_shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Drop any jobs that never ran (only possible if the user dropped
        // the scheduler without `wait_idle`); their futures resolve to a
        // broken-promise error via `Promise::drop`.
        // SAFETY: every worker has been joined above — this thread is the
        // sole owner of every queue now.
        for q in &self.pool.queues {
            drop(unsafe { q.drain() });
        }
        drop(self.pool.injector.take_all());
    }
}

/// Process-wide exec-span ids for the flight recorder (only advanced
/// while tracing is on).
static EXEC_SEQ: AtomicU64 = AtomicU64::new(0);

/// Submit `job` to `pool`, preferring the current worker's local queue.
pub fn spawn_on(pool: &Arc<Pool>, job: Job) {
    // Relaxed: the spawn count is published to whoever needs it by
    // stronger edges — the queue push (release) hands it to the worker
    // that runs the job, and that worker's completion RMW (SeqCst)
    // hands it to idle waiters. No one reads `spawned` expecting this
    // increment without first crossing one of those edges.
    let seq = pool.spawned.fetch_add(1, Ordering::Relaxed) + 1;
    crate::trace::emit(crate::trace::EventKind::Spawn, seq, 0);
    let local = CURRENT.with(|c| {
        c.borrow()
            .as_ref()
            .and_then(|(p, idx)| Arc::ptr_eq(p, pool).then_some(*idx))
    });
    match local {
        // SAFETY: `local` is only Some when the current thread is worker
        // idx of *this* pool — the queue's one owner.
        Some(idx) => unsafe { pool.queues[idx].push(job) },
        None => pool.injector.push(job),
    }
    pool.notify_one();
}

/// The (pool, worker index) of the current thread, if it is a worker.
pub fn current_worker() -> Option<(Arc<Pool>, usize)> {
    CURRENT.with(|c| c.borrow().as_ref().map(|(p, idx)| (Arc::clone(p), *idx)))
}

pub(crate) fn set_current(pool: &Arc<Pool>, idx: usize) {
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(pool), idx)));
}

pub(crate) fn clear_current() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}
