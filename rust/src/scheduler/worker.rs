//! The worker-thread main loop.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::{clear_current, set_current, Pool};

/// Body of each `rhpx-worker-N` thread.
///
/// Loop: execute whatever [`Pool::find_job`] yields (local LIFO →
/// injector → steal); when nothing is runnable, park on the pool condvar
/// using the lost-wakeup-safe protocol (increment `idle` *under the sleep
/// lock*, re-check the queues, then wait — submitters push first and only
/// then read `idle`, so either they observe us idle and notify, or we
/// observe their job on the re-check).
pub(super) fn worker_loop(pool: Arc<Pool>, idx: usize) {
    set_current(&pool, idx);
    // Per-worker steal-victim RNG state; seeded by index so the scan
    // pattern differs between workers.
    let mut rng: u64 = 0x9e3779b97f4a7c15u64.wrapping_mul(idx as u64 + 1);

    loop {
        if pool.shutdown.load(Ordering::SeqCst) {
            break;
        }
        if let Some(job) = pool.find_job(idx, &mut rng) {
            pool.run_job(job);
            continue;
        }
        // Nothing runnable: park.
        let guard = pool.sleep_lock.lock().unwrap();
        if pool.shutdown.load(Ordering::SeqCst) {
            break;
        }
        pool.idle.fetch_add(1, Ordering::SeqCst);
        if pool.has_work() {
            // A job arrived between the failed scan and taking the lock.
            pool.idle.fetch_sub(1, Ordering::SeqCst);
            drop(guard);
            continue;
        }
        // Timed wait as a belt-and-braces guard: correctness does not
        // depend on the timeout, it only bounds the cost of a missed
        // wakeup under exotic schedulers.
        let (guard, _timeout) = pool
            .sleep_cv
            .wait_timeout(guard, std::time::Duration::from_millis(10))
            .unwrap();
        pool.idle.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
    }
    clear_current();
}
