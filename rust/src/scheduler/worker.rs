//! The worker-thread main loop.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::{clear_current, set_current, Pool};

/// Body of each `rhpx-worker-N` thread.
///
/// Loop: execute whatever [`Pool::find_job`] yields (local LIFO →
/// injector batch → steal); when nothing is runnable, park on the pool
/// condvar using the wake-counter protocol: increment `sleepers`, snap
/// the wake epoch, re-scan the queues, and sleep only while the epoch is
/// unchanged. Submitters bump the epoch *before* reading `sleepers`
/// (both `SeqCst`), so either they observe us parked and notify, or we
/// observe their bump (or their job) on the re-check. The submitter-side
/// notify is issued without holding the sleep lock; the epoch re-check
/// covers the unlocked race, and the timed wait merely bounds the cost
/// of the theoretical residue — correctness does not depend on it.
pub(super) fn worker_loop(pool: Arc<Pool>, idx: usize) {
    set_current(&pool, idx);
    // Per-worker steal-victim RNG state; seeded by index so the scan
    // pattern differs between workers.
    let mut rng: u64 = 0x9e3779b97f4a7c15u64.wrapping_mul(idx as u64 + 1);

    loop {
        // Acquire: pairs with the shutdown store + epoch bump.
        if pool.shutdown.load(Ordering::Acquire) {
            break;
        }
        if let Some(job) = pool.find_job(idx, &mut rng) {
            pool.run_job(job);
            continue;
        }
        // Nothing runnable: commit to parking.
        let mut guard = pool.sleep_lock.lock().unwrap();
        if pool.shutdown.load(Ordering::Acquire) {
            break;
        }
        // SeqCst: Dekker with `Pool::notify_one` (see scheduler docs).
        pool.sleepers.fetch_add(1, Ordering::SeqCst);
        let epoch = pool.wake_epoch.load(Ordering::SeqCst);
        if pool.has_work() {
            // A job arrived between the failed scan and committing.
            pool.sleepers.fetch_sub(1, Ordering::SeqCst);
            drop(guard);
            continue;
        }
        while pool.wake_epoch.load(Ordering::SeqCst) == epoch
            && !pool.shutdown.load(Ordering::Relaxed)
        {
            let (g, timeout) = pool
                .sleep_cv
                .wait_timeout(guard, Duration::from_millis(10))
                .unwrap();
            guard = g;
            if timeout.timed_out() {
                break;
            }
        }
        pool.sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
    }
    clear_current();
}
