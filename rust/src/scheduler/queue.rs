//! Per-worker task queues.
//!
//! HPX uses lock-free Chase–Lev deques; on this single-vCPU testbed a
//! mutex-guarded deque with LIFO local pop and FIFO steal has the same
//! scheduling semantics (depth-first local execution, breadth-first
//! stealing) with negligible contention cost relative to the paper's
//! 200 µs task grains. The queue API mirrors the classic work-stealing
//! deque so a lock-free implementation can be dropped in behind it.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::Job;

/// A work-stealing deque: the owning worker pushes/pops at the back
/// (LIFO, cache-friendly); thieves steal from the front (FIFO, oldest
/// and typically largest subtree of work).
pub struct WorkQueue {
    inner: Mutex<VecDeque<Job>>,
}

impl WorkQueue {
    pub fn new() -> Self {
        WorkQueue { inner: Mutex::new(VecDeque::new()) }
    }

    /// Owner-side push (back).
    pub fn push(&self, job: Job) {
        self.inner.lock().unwrap().push_back(job);
    }

    /// Owner-side pop (back, LIFO).
    pub fn pop(&self) -> Option<Job> {
        self.inner.lock().unwrap().pop_back()
    }

    /// Thief-side steal (front, FIFO).
    pub fn steal(&self) -> Option<Job> {
        self.inner.lock().unwrap().pop_front()
    }

    /// Number of queued jobs (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain every queued job (used at shutdown).
    pub fn drain(&self) -> Vec<Job> {
        self.inner.lock().unwrap().drain(..).collect()
    }
}

impl Default for WorkQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn job(counter: &Arc<AtomicUsize>, v: usize) -> Job {
        let c = Arc::clone(counter);
        Box::new(move || {
            c.fetch_add(v, Ordering::SeqCst);
        })
    }

    #[test]
    fn lifo_pop_fifo_steal() {
        let q = WorkQueue::new();
        let c = Arc::new(AtomicUsize::new(0));
        q.push(job(&c, 1));
        q.push(job(&c, 10));
        q.push(job(&c, 100));
        assert_eq!(q.len(), 3);
        // Owner pop gets the newest (100); thief steal gets the oldest (1).
        let newest = q.pop().unwrap();
        let oldest = q.steal().unwrap();
        newest();
        assert_eq!(c.load(Ordering::SeqCst), 100);
        oldest();
        assert_eq!(c.load(Ordering::SeqCst), 101);
        q.pop().unwrap()(); // remaining middle job
        assert_eq!(c.load(Ordering::SeqCst), 111);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        assert!(q.steal().is_none());
    }

    #[test]
    fn drain_returns_all() {
        let q = WorkQueue::new();
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..5 {
            q.push(job(&c, 1));
        }
        let jobs = q.drain();
        assert_eq!(jobs.len(), 5);
        assert!(q.is_empty());
    }
}
