//! Per-worker task queues — lock-free hot paths.
//!
//! [`WorkQueue`] is a real Chase–Lev work-stealing deque (atomic
//! `top`/`bottom` indices over a growable circular buffer): the owning
//! worker pushes and pops at the bottom with no atomic RMW on the common
//! path, thieves steal at the top with a single CAS. The memory orderings
//! follow Lê, Pop, Cohen & Nardelli, *Correct and Efficient Work-Stealing
//! for Weak Memory Models* (PPoPP'13) — each non-`SeqCst` ordering below
//! carries a one-line justification, and the two `SeqCst` fences are
//! exactly the store-load barriers of that paper.
//!
//! [`Injector`] is the multi-producer submission queue for jobs spawned
//! from *non-worker* threads: a Treiber stack (one CAS per push, no
//! lock), consumed in whole batches by a single `swap` — the consumer
//! moves the batch into its local deque, whose LIFO pop then yields the
//! batch in submission (FIFO) order. Taking the whole chain at once
//! sidesteps the ABA and reclamation hazards of lock-free multi-consumer
//! pops entirely: the taker owns every node it walks.
//!
//! Retired deque buffers (outgrown by `grow`) are kept alive until the
//! deque drops, so a thief holding a stale buffer pointer always reads
//! valid memory; a stale read is discarded when its `top` CAS fails.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, Ordering};
use std::sync::Mutex;

use super::Job;

/// Initial deque capacity (doubles on overflow; must be a power of two).
const INITIAL_CAP: usize = 64;

/// Growable circular buffer of jobs. Slots are `MaybeUninit`: liveness is
/// tracked entirely by the `top`/`bottom` indices of the owning deque, so
/// retiring a buffer after `grow` never double-drops a job.
struct Buffer {
    cap: usize,
    slots: Box<[UnsafeCell<MaybeUninit<Job>>]>,
}

impl Buffer {
    fn alloc(cap: usize) -> *mut Buffer {
        debug_assert!(cap.is_power_of_two());
        let slots = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect();
        Box::into_raw(Box::new(Buffer { cap, slots }))
    }

    /// # Safety
    /// The caller must hold the owner-side right to write slot `idx`
    /// (Chase–Lev invariant: only the owner writes, only between
    /// `top`..`bottom` wraparounds that the indices rule out).
    #[inline]
    unsafe fn write(&self, idx: isize, job: Job) {
        self.write_raw(idx, MaybeUninit::new(job));
    }

    #[inline]
    unsafe fn write_raw(&self, idx: isize, val: MaybeUninit<Job>) {
        let slot = &self.slots[idx as usize & (self.cap - 1)];
        ptr::write(slot.get(), val);
    }

    /// Copy a slot's raw bits. Deliberately returns `MaybeUninit`: a
    /// thief may read a slot that is stale (already consumed, or never
    /// copied into a grown buffer), so materializing a `Job` (a `Box`,
    /// with validity invariants) here would be UB. Callers
    /// `assume_init` only *after* winning the index via the `top` CAS /
    /// `bottom` arbitration; losers just discard the bits (no-op drop).
    ///
    /// # Safety
    /// `idx` must be in-bounds of the ring (any value is — it is
    /// masked); the bits are only meaningful once the index is won.
    #[inline]
    unsafe fn read(&self, idx: isize) -> MaybeUninit<Job> {
        let slot = &self.slots[idx as usize & (self.cap - 1)];
        ptr::read(slot.get())
    }
}

/// A Chase–Lev work-stealing deque: the owning worker pushes/pops at the
/// bottom (LIFO, cache-friendly, no CAS off the contended path); thieves
/// steal from the top (FIFO, oldest and typically largest subtree of
/// work) with one CAS.
///
/// The owner-side calls (`push`, `pop`, `drain`) are `unsafe`: the
/// algorithm requires that at most one thread at a time acts as the
/// owner (the scheduler guarantees it — each queue's owner methods are
/// only invoked from its worker's thread, or from the single-threaded
/// shutdown path). `steal`/`len`/`is_empty` are safe from any thread.
pub struct WorkQueue {
    /// Next index a thief will steal (grows monotonically).
    top: AtomicIsize,
    /// Next index the owner will push (owner-written).
    bottom: AtomicIsize,
    buf: AtomicPtr<Buffer>,
    /// Buffers outgrown by `grow`, kept alive so concurrent thieves with
    /// stale buffer pointers never touch freed memory. Cold path: locked
    /// only while growing and at drop.
    retired: Mutex<Vec<*mut Buffer>>,
}

// SAFETY: the Chase–Lev protocol (indices + CAS arbitration) guarantees
// each job is handed to exactly one thread; `Job` is `Send`.
unsafe impl Send for WorkQueue {}
unsafe impl Sync for WorkQueue {}

impl WorkQueue {
    pub fn new() -> Self {
        WorkQueue {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buffer::alloc(INITIAL_CAP)),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Owner-side push (bottom). No RMW: one release store publishes the
    /// job to thieves.
    ///
    /// # Safety
    /// Must not run concurrently with any other owner-side call
    /// (`push`/`pop`/`drain`) on this queue; concurrent `steal` is fine.
    pub unsafe fn push(&self, job: Job) {
        // Relaxed: `bottom` is only written by this (owner) thread.
        let b = self.bottom.load(Ordering::Relaxed);
        // Acquire: pairs with thieves' top CAS so the owner observes how
        // far stealing has advanced before deciding whether to grow.
        let t = self.top.load(Ordering::Acquire);
        let mut buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        if b - t >= buf.cap as isize {
            self.grow(t, b);
            buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        }
        unsafe { buf.write(b, job) };
        // Release: publishes the slot write (and everything the spawner
        // did before it) to any thief that acquires `bottom`.
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner-side pop (bottom, LIFO).
    ///
    /// # Safety
    /// Must not run concurrently with any other owner-side call
    /// (`push`/`pop`/`drain`) on this queue; concurrent `steal` is fine.
    pub unsafe fn pop(&self) -> Option<Job> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        // Relaxed store + SeqCst fence: the fence is the store-load
        // barrier between our `bottom` write and the `top` read (Lê et
        // al. Fig. 1); the store itself needs no release because thieves
        // re-check `bottom` after their own fence.
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            // Non-empty. Raw-copy before the potential CAS; the bits
            // only become a `Job` once we have won index `b`.
            let job = unsafe { buf.read(b) };
            if t == b {
                // Single element left: race thieves via CAS on top.
                // SeqCst success: total order with the thief's CAS
                // decides who owns the final job.
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_err()
                {
                    // Lost: the thief owns the job; our copy is just
                    // uninteresting bits (MaybeUninit drop is a no-op).
                    self.bottom.store(b + 1, Ordering::Relaxed);
                    return None;
                }
                self.bottom.store(b + 1, Ordering::Relaxed);
            }
            // Won (by bottom decrement, or by the CAS above).
            Some(unsafe { job.assume_init() })
        } else {
            // Empty: restore bottom.
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief-side steal (top, FIFO). Any thread.
    pub fn steal(&self) -> Option<Job> {
        loop {
            // Acquire: see the owner's writes up to the top we read.
            let t = self.top.load(Ordering::Acquire);
            // SeqCst fence: store-load barrier ordering our top read
            // before the bottom read (mirror of pop's fence).
            fence(Ordering::SeqCst);
            // Acquire: pairs with push's release store so the slot write
            // is visible before we read it.
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return None;
            }
            // Acquire: pairs with grow's release store of the new buffer
            // pointer, so we never read through a partially-copied buffer.
            let buf = unsafe { &*self.buf.load(Ordering::Acquire) };
            // Raw bits only — this slot may be stale if we are racing a
            // grow or other thieves; the CAS below decides ownership.
            let job = unsafe { buf.read(t) };
            // SeqCst: arbitration with the owner's last-element CAS and
            // competing thieves; only the winner keeps the bits read.
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                return Some(unsafe { job.assume_init() });
            }
            // Lost the race: the bits belong to whoever advanced top;
            // dropping the MaybeUninit copy is a no-op.
        }
    }

    /// Double the buffer (owner-side). The old buffer is retired, not
    /// freed: thieves that loaded it before the swap still read valid
    /// slots, and their `top` CAS discards any job the copy superseded.
    fn grow(&self, t: isize, b: isize) {
        let old_ptr = self.buf.load(Ordering::Relaxed);
        let old = unsafe { &*old_ptr };
        let new_ptr = Buffer::alloc(old.cap * 2);
        let new = unsafe { &*new_ptr };
        for i in t..b {
            // Raw bit-copy (never materialized as `Job`s): some of
            // t..b may already have been stolen — their bits are stale
            // and must not be treated as live boxes; liveness stays
            // with the indices.
            unsafe { new.write_raw(i, old.read(i)) };
        }
        // Release: a thief acquiring this pointer sees every copied slot.
        self.buf.store(new_ptr, Ordering::Release);
        self.retired.lock().unwrap().push(old_ptr);
    }

    /// Number of queued jobs (approximate under concurrency).
    pub fn len(&self) -> usize {
        // Relaxed pair: the result is advisory (idle heuristics only).
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain every queued job. Owner-side (used at shutdown, after the
    /// worker threads have been joined).
    ///
    /// # Safety
    /// As [`WorkQueue::pop`]: no concurrent owner-side calls.
    pub unsafe fn drain(&self) -> Vec<Job> {
        let mut out = Vec::new();
        while let Some(j) = self.pop() {
            out.push(j);
        }
        out
    }
}

impl Default for WorkQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for WorkQueue {
    fn drop(&mut self) {
        // Drop any jobs that never ran (their promises resolve to
        // broken-promise errors as the closures drop).
        // SAFETY: `&mut self` — no concurrent access of any kind.
        while let Some(job) = unsafe { self.pop() } {
            drop(job);
        }
        unsafe {
            drop(Box::from_raw(self.buf.load(Ordering::Relaxed)));
            for p in self.retired.lock().unwrap().drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

/// One queued external submission.
struct InjectorNode {
    job: Job,
    next: *mut InjectorNode,
}

/// Lock-free multi-producer submission queue for spawns from non-worker
/// threads: pushes are a single CAS on the head of a Treiber stack;
/// consumption takes the *entire* chain with one `swap` (see
/// [`Injector::take_all`]), which makes reclamation trivial (the taker
/// owns every node) and rules out ABA by construction.
pub struct Injector {
    head: AtomicPtr<InjectorNode>,
}

// SAFETY: nodes are owned by exactly one side at any time (producers
// until the CAS succeeds, the taking consumer afterwards); `Job` is Send.
unsafe impl Send for Injector {}
unsafe impl Sync for Injector {}

impl Injector {
    pub fn new() -> Self {
        Injector { head: AtomicPtr::new(ptr::null_mut()) }
    }

    /// Submit a job. Lock-free; any thread.
    pub fn push(&self, job: Job) {
        let node = Box::into_raw(Box::new(InjectorNode { job, next: ptr::null_mut() }));
        // Relaxed load + Release CAS: the CAS publishes the node (and the
        // job it carries); failure retries with the fresher head.
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            unsafe { (*node).next = head };
            match self.head.compare_exchange_weak(
                head,
                node,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(h) => head = h,
            }
        }
    }

    /// True when no submission is pending. Advisory (idle heuristics).
    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Relaxed).is_null()
    }

    /// Take every queued submission in one swap. The returned batch
    /// yields jobs newest-first (stack order); pushing them into a
    /// [`WorkQueue`] in that order makes the owner's LIFO `pop` consume
    /// them oldest-first, i.e. in submission order.
    pub fn take_all(&self) -> InjectorBatch {
        // Acquire: pairs with push's release CAS so every job in the
        // chain is fully visible to the taker.
        InjectorBatch { head: self.head.swap(ptr::null_mut(), Ordering::Acquire) }
    }
}

impl Default for Injector {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Injector {
    fn drop(&mut self) {
        drop(self.take_all());
    }
}

/// An owned chain of submissions taken from an [`Injector`]; iterating
/// frees each node as its job is handed out.
pub struct InjectorBatch {
    head: *mut InjectorNode,
}

// SAFETY: the batch exclusively owns its chain.
unsafe impl Send for InjectorBatch {}

impl Iterator for InjectorBatch {
    type Item = Job;

    fn next(&mut self) -> Option<Job> {
        if self.head.is_null() {
            return None;
        }
        let node = unsafe { Box::from_raw(self.head) };
        self.head = node.next;
        Some(node.job)
    }
}

impl Drop for InjectorBatch {
    fn drop(&mut self) {
        // Drop any jobs not handed out (shutdown path).
        for job in self.by_ref() {
            drop(job);
        }
    }
}

/// Per-task lineage record for resilient work stealing (arXiv
/// 1706.03539): where a task was placed, which task (if any) it was
/// re-materialized from, and a monotonically increasing epoch that
/// orders spawns cluster-wide.
///
/// Lineage does *not* ride inside the [`WorkQueue`]/[`Injector`] nodes —
/// those hot paths stay pointer-sized (PR-4's throughput depends on it).
/// Instead it lives in a [`LineageLedger`] side table keyed by epoch:
/// the distributed layer records an entry per routed task, the executing
/// job *claims* its epoch just before running, and a locality kill
/// *drains* whatever is still unclaimed — the queued-but-unexecuted
/// set — handing each entry's relaunch closure to a survivor. Claim and
/// drain are mutually exclusive per epoch, so a task is never both
/// executed on the corpse and re-materialized elsewhere.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lineage {
    /// Locality the task was originally routed to.
    pub origin: usize,
    /// Epoch of the spawn this task was re-materialized from (`None`
    /// for a first placement).
    pub parent: Option<u64>,
    /// Cluster-wide monotonic spawn epoch (the ledger key).
    pub epoch: u64,
}

/// The queued-but-unexecuted side table backing [`Lineage`] tracking.
///
/// One ledger per locality mailbox/deque pair. `BTreeMap` (not
/// `HashMap`) so [`LineageLedger::drain`] re-materializes in epoch
/// (spawn) order — deterministic replays for the scripted-interleaving
/// tests, FIFO fairness in production.
pub struct LineageLedger {
    pending: Mutex<std::collections::BTreeMap<u64, (Lineage, Job)>>,
}

impl LineageLedger {
    pub fn new() -> Self {
        LineageLedger { pending: Mutex::new(std::collections::BTreeMap::new()) }
    }

    /// Record a routed-but-not-yet-executed task: its lineage and the
    /// relaunch closure a drain hands to a survivor.
    pub fn record(&self, lineage: Lineage, relaunch: Job) {
        self.pending.lock().unwrap().insert(lineage.epoch, (lineage, relaunch));
    }

    /// Executor-side claim: the job for `epoch` is about to run. Returns
    /// `true` when this caller won the entry (it must run the task) and
    /// `false` when a drain already re-materialized it (the caller must
    /// do nothing — the task now belongs to a survivor).
    pub fn claim(&self, epoch: u64) -> bool {
        self.pending.lock().unwrap().remove(&epoch).is_some()
    }

    /// Kill-side drain: claim *every* pending entry at once, in epoch
    /// order. Each returned closure re-materializes its task elsewhere.
    pub fn drain(&self) -> Vec<(Lineage, Job)> {
        let mut map = self.pending.lock().unwrap();
        let drained = std::mem::take(&mut *map);
        drained.into_values().collect()
    }

    /// Lineages currently pending (diagnostics and tests).
    pub fn lineages(&self) -> Vec<Lineage> {
        self.pending.lock().unwrap().values().map(|(l, _)| l.clone()).collect()
    }

    /// Number of queued-but-unexecuted tasks.
    pub fn len(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for LineageLedger {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    // All owner-side calls below run single-threaded (or from the one
    // designated owner thread), satisfying the unsafe contract.

    fn job(counter: &Arc<AtomicUsize>, v: usize) -> Job {
        let c = Arc::clone(counter);
        Box::new(move || {
            c.fetch_add(v, Ordering::SeqCst);
        })
    }

    #[test]
    fn lifo_pop_fifo_steal() {
        let q = WorkQueue::new();
        let c = Arc::new(AtomicUsize::new(0));
        unsafe {
            q.push(job(&c, 1));
            q.push(job(&c, 10));
            q.push(job(&c, 100));
        }
        assert_eq!(q.len(), 3);
        // Owner pop gets the newest (100); thief steal gets the oldest (1).
        let newest = unsafe { q.pop() }.unwrap();
        let oldest = q.steal().unwrap();
        newest();
        assert_eq!(c.load(Ordering::SeqCst), 100);
        oldest();
        assert_eq!(c.load(Ordering::SeqCst), 101);
        unsafe { q.pop() }.unwrap()(); // remaining middle job
        assert_eq!(c.load(Ordering::SeqCst), 111);
        assert!(q.is_empty());
        assert!(unsafe { q.pop() }.is_none());
        assert!(q.steal().is_none());
    }

    #[test]
    fn drain_returns_all() {
        let q = WorkQueue::new();
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..5 {
            unsafe { q.push(job(&c, 1)) };
        }
        let jobs = unsafe { q.drain() };
        assert_eq!(jobs.len(), 5);
        assert!(q.is_empty());
    }

    #[test]
    fn growth_past_initial_capacity_preserves_every_job() {
        let q = WorkQueue::new();
        let c = Arc::new(AtomicUsize::new(0));
        let n = INITIAL_CAP * 4 + 3; // force two grows
        for _ in 0..n {
            unsafe { q.push(job(&c, 1)) };
        }
        assert_eq!(q.len(), n);
        let mut ran = 0;
        while let Some(j) = unsafe { q.pop() } {
            j();
            ran += 1;
        }
        assert_eq!(ran, n);
        assert_eq!(c.load(Ordering::SeqCst), n);
    }

    #[test]
    fn interleaved_push_pop_steal_single_thread() {
        let q = WorkQueue::new();
        let c = Arc::new(AtomicUsize::new(0));
        let mut queued = 0usize;
        let mut handed = 0usize;
        for round in 0..1000usize {
            unsafe { q.push(job(&c, 1)) };
            queued += 1;
            if round % 3 == 0 && q.steal().is_some() {
                handed += 1;
            } else if round % 3 != 0 && round % 7 == 0 && unsafe { q.pop() }.is_some() {
                handed += 1;
            }
        }
        while unsafe { q.pop() }.is_some() {
            handed += 1;
        }
        assert_eq!(handed, queued);
        assert!(q.is_empty());
    }

    #[test]
    fn injector_batches_in_submission_order_via_lifo_pop() {
        let inj = Injector::new();
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        for i in 0..5 {
            let order = Arc::clone(&order);
            inj.push(Box::new(move || order.lock().unwrap().push(i)));
        }
        assert!(!inj.is_empty());
        // Consume the way the scheduler does: batch -> local deque -> pop.
        let q = WorkQueue::new();
        for j in inj.take_all() {
            unsafe { q.push(j) };
        }
        assert!(inj.is_empty());
        while let Some(j) = unsafe { q.pop() } {
            j();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lineage_claim_and_drain_are_mutually_exclusive() {
        let ledger = LineageLedger::new();
        let hits = Arc::new(AtomicUsize::new(0));
        for epoch in 0..4u64 {
            ledger.record(
                Lineage { origin: 2, parent: None, epoch },
                job(&hits, 1 << epoch),
            );
        }
        assert_eq!(ledger.len(), 4);
        // The executor claims epoch 1; a later drain must not see it.
        assert!(ledger.claim(1));
        assert!(!ledger.claim(1), "double claim must lose");
        let drained = ledger.drain();
        assert_eq!(drained.len(), 3);
        // Epoch (spawn) order, and each job exactly once.
        let epochs: Vec<u64> = drained.iter().map(|(l, _)| l.epoch).collect();
        assert_eq!(epochs, vec![0, 2, 3]);
        for (_, relaunch) in drained {
            relaunch();
        }
        assert_eq!(hits.load(Ordering::SeqCst), 0b1101);
        assert!(ledger.is_empty());
        assert!(!ledger.claim(0), "drained epochs cannot be claimed");
    }

    #[test]
    fn lineage_records_parent_chain() {
        let ledger = LineageLedger::new();
        ledger.record(Lineage { origin: 0, parent: None, epoch: 7 }, Box::new(|| {}));
        ledger.record(Lineage { origin: 3, parent: Some(7), epoch: 8 }, Box::new(|| {}));
        let lins = ledger.lineages();
        assert_eq!(lins.len(), 2);
        assert_eq!(lins[0], Lineage { origin: 0, parent: None, epoch: 7 });
        assert_eq!(lins[1].parent, Some(7), "re-materialized spawn keeps its parent");
    }

    #[test]
    fn injector_drop_releases_pending_jobs() {
        let c = Arc::new(AtomicUsize::new(0));
        struct Probe(Arc<AtomicUsize>);
        impl Drop for Probe {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let inj = Injector::new();
        for _ in 0..3 {
            let p = Probe(Arc::clone(&c));
            inj.push(Box::new(move || {
                let _keep = &p;
            }));
        }
        drop(inj);
        assert_eq!(c.load(Ordering::SeqCst), 3, "unrun jobs must drop their closures");
    }
}
