//! Artifact discovery and naming.
//!
//! Artifacts follow the naming convention emitted by
//! `python/compile/aot.py`: `stencil_nx<points>_s<steps>.hlo.txt` for the
//! Lax-Wendroff subdomain kernel, plus free-form names for auxiliary
//! kernels. The store maps logical names to paths and answers staleness
//! queries for `make artifacts`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{TaskError, TaskResult};

/// Directory of AOT artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
    entries: BTreeMap<String, PathBuf>,
}

impl ArtifactStore {
    /// Scan `dir` for `*.hlo.txt` artifacts.
    ///
    /// A missing directory is not an error: it yields an *empty* store,
    /// so PJRT-dependent callers can probe with
    /// [`ArtifactStore::is_empty`] and skip cleanly on a bare checkout
    /// instead of panicking (they print their own skip note — the
    /// library stays silent). Individual lookups on an empty store still
    /// fail with a "run `make artifacts`" error.
    pub fn open(dir: &Path) -> TaskResult<Self> {
        let mut entries = BTreeMap::new();
        if !dir.exists() {
            return Ok(ArtifactStore { dir: dir.to_path_buf(), entries });
        }
        let rd = std::fs::read_dir(dir)
            .map_err(|e| TaskError::Runtime(format!("artifacts dir {}: {e}", dir.display())))?;
        for entry in rd.flatten() {
            let path = entry.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                entries.insert(stem.to_string(), path.clone());
            }
        }
        Ok(ArtifactStore { dir: dir.to_path_buf(), entries })
    }

    /// Logical name of the stencil kernel artifact for a subdomain size
    /// and step count.
    pub fn stencil_name(nx: usize, steps: usize) -> String {
        format!("stencil_nx{nx}_s{steps}")
    }

    /// Path of a named artifact.
    pub fn path(&self, name: &str) -> TaskResult<&Path> {
        self.entries
            .get(name)
            .map(|p| p.as_path())
            .ok_or_else(|| {
                TaskError::Runtime(format!(
                    "artifact '{name}' not found in {} (have: {}); run `make artifacts`",
                    self.dir.display(),
                    self.names().collect::<Vec<_>>().join(", ")
                ))
            })
    }

    /// Path for a stencil kernel configuration.
    pub fn stencil_path(&self, nx: usize, steps: usize) -> TaskResult<&Path> {
        self.path(&Self::stencil_name(nx, steps))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naming_convention() {
        assert_eq!(ArtifactStore::stencil_name(16000, 128), "stencil_nx16000_s128");
    }

    #[test]
    fn scans_directory() {
        let dir = std::env::temp_dir().join(format!("rhpx_art_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("stencil_nx64_s4.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(dir.join("notes.md"), "not an artifact").unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.len(), 1);
        assert!(store.stencil_path(64, 4).is_ok());
        assert!(store.path("missing").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_clean_empty_store() {
        // A bare checkout has no artifacts/: open() must not fail (tier-1
        // runs without Python), only individual lookups do.
        let store = ArtifactStore::open(Path::new("/definitely/not/here")).unwrap();
        assert!(store.is_empty());
        assert_eq!(store.len(), 0);
        let err = store.stencil_path(64, 4).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
