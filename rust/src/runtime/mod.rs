//! PJRT execution of AOT-compiled artifacts — the L3↔L1/L2 bridge.
//!
//! Paper mapping: the `--backend pjrt` kernel path of the §V-B stencil
//! (Table II / Fig 3); the resilience layers above are backend-agnostic.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time,
//! lowering the JAX/Pallas stencil kernel to **HLO text** under
//! `artifacts/` (text, not serialized proto: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids). This module loads those artifacts and executes them
//! through the PJRT CPU client of the `xla` crate.
//!
//! The PJRT path is gated behind the `pjrt` cargo feature because the
//! `xla` crate must be vendored into the build environment (it is not on
//! crates.io and the default build is fully offline with zero external
//! dependencies). Without the feature, [`execute_f64`] and [`warmup`]
//! return a `TaskError::Runtime` describing the situation, and every
//! PJRT-backed test, bench, and harness checks [`pjrt_available`] first
//! and skips cleanly — tier-1 verification stays green on a bare
//! checkout with no artifacts and no PJRT runtime.
//!
//! With the feature enabled, the `xla` crate's `PjRtClient` is
//! `Rc`-based (not `Send`), so each worker thread lazily creates its own
//! client and compiles artifacts into a thread-local executable cache:
//! compilation happens once per (thread, artifact) and the request path
//! afterwards is a pure in-thread PJRT execute with no locks and no
//! Python.

mod artifact;

pub use artifact::ArtifactStore;

use std::path::Path;

use crate::error::TaskResult;

/// True when this build carries a working PJRT execution engine.
///
/// Callers that depend on AOT artifacts (the `Backend::Pjrt` stencil
/// path, `tests/integration_pjrt.rs`, ablation A5) must skip — not fail —
/// when this returns `false`.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Execute the artifact at `path` with 1-D `f64` inputs, returning the
/// flattened `f64` outputs of the (tupled) result.
///
/// Artifacts are lowered with `return_tuple=True`; multi-output kernels
/// come back as a tuple whose leaves are returned in order.
pub fn execute_f64(path: &Path, inputs: &[&[f64]]) -> TaskResult<Vec<Vec<f64>>> {
    engine::execute_f64(path, inputs)
}

/// Number of executables cached on the current thread (diagnostics).
pub fn cached_executables() -> usize {
    engine::cached_executables()
}

/// Pre-compile an artifact on the current thread so first-task latency
/// doesn't include compilation (benchmark warmup).
pub fn warmup(path: &Path) -> TaskResult<()> {
    engine::warmup(path)
}

/// The error every PJRT entry point returns when the engine is not
/// compiled in.
#[cfg(not(feature = "pjrt"))]
fn unavailable(path: &Path) -> crate::error::TaskError {
    crate::error::TaskError::Runtime(format!(
        "PJRT engine not compiled in (requires a vendored `xla` dependency plus \
         `--features pjrt`; see rust/Cargo.toml) — cannot execute {}; \
         use Backend::Native or skip",
        path.display()
    ))
}

#[cfg(not(feature = "pjrt"))]
mod engine {
    //! Stub engine: every call reports PJRT as unavailable.

    use std::path::Path;

    use crate::error::TaskResult;

    pub fn execute_f64(path: &Path, _inputs: &[&[f64]]) -> TaskResult<Vec<Vec<f64>>> {
        Err(super::unavailable(path))
    }

    pub fn cached_executables() -> usize {
        0
    }

    pub fn warmup(path: &Path) -> TaskResult<()> {
        Err(super::unavailable(path))
    }
}

#[cfg(feature = "pjrt")]
mod engine {
    //! Real engine: thread-local PJRT CPU client + executable cache.

    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use crate::error::{TaskError, TaskResult};

    thread_local! {
        static ENGINE: RefCell<Option<ThreadEngine>> = const { RefCell::new(None) };
    }

    struct ThreadEngine {
        client: xla::PjRtClient,
        cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    }

    impl ThreadEngine {
        fn new() -> TaskResult<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| TaskError::Runtime(format!("PjRtClient::cpu: {e}")))?;
            Ok(ThreadEngine { client, cache: HashMap::new() })
        }

        fn executable(&mut self, path: &Path) -> TaskResult<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(path) {
                let proto = xla::HloModuleProto::from_text_file(path.to_str().ok_or_else(
                    || TaskError::Runtime(format!("non-utf8 artifact path {path:?}")),
                )?)
                .map_err(|e| TaskError::Runtime(format!("parse {}: {e}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| TaskError::Runtime(format!("compile {}: {e}", path.display())))?;
                self.cache.insert(path.to_path_buf(), exe);
            }
            Ok(self.cache.get(path).expect("just inserted"))
        }
    }

    pub fn execute_f64(path: &Path, inputs: &[&[f64]]) -> TaskResult<Vec<Vec<f64>>> {
        ENGINE.with(|cell| {
            let mut slot = cell.borrow_mut();
            if slot.is_none() {
                *slot = Some(ThreadEngine::new()?);
            }
            let engine = slot.as_mut().expect("initialized above");
            let exe = engine.executable(path)?;
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|x| xla::Literal::vec1(x)).collect();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| TaskError::Runtime(format!("execute {}: {e}", path.display())))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| TaskError::Runtime(format!("to_literal: {e}")))?;
            let tuple = out
                .to_tuple()
                .map_err(|e| TaskError::Runtime(format!("to_tuple: {e}")))?;
            let mut vecs = Vec::with_capacity(tuple.len());
            for leaf in tuple {
                vecs.push(
                    leaf.to_vec::<f64>()
                        .map_err(|e| TaskError::Runtime(format!("to_vec<f64>: {e}")))?,
                );
            }
            Ok(vecs)
        })
    }

    pub fn cached_executables() -> usize {
        ENGINE.with(|cell| cell.borrow().as_ref().map_or(0, |e| e.cache.len()))
    }

    pub fn warmup(path: &Path) -> TaskResult<()> {
        ENGINE.with(|cell| {
            let mut slot = cell.borrow_mut();
            if slot.is_none() {
                *slot = Some(ThreadEngine::new()?);
            }
            slot.as_mut().expect("initialized").executable(path).map(|_| ())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::TaskError;

    #[test]
    fn stub_reports_unavailable_without_feature() {
        if pjrt_available() {
            return; // real engine compiled in; covered by integration_pjrt
        }
        let err = execute_f64(Path::new("artifacts/none.hlo.txt"), &[&[1.0]]).unwrap_err();
        match err {
            TaskError::Runtime(m) => assert!(m.contains("PJRT"), "{m}"),
            other => panic!("unexpected: {other:?}"),
        }
        assert!(warmup(Path::new("artifacts/none.hlo.txt")).is_err());
        assert_eq!(cached_executables(), 0);
    }
}
