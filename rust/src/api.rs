//! The base launch API: `async_` and `dataflow`.
//!
//! These are the HPX facilities (`hpx::async`, `hpx::dataflow`) that the
//! resiliency layer of the paper extends: "all new functionalities are
//! implemented as extensions of the existing HPX async and dataflow API
//! functions" (§IV). A task body is any `FnOnce() -> R` where `R`
//! converts into a [`TaskResult`]; panics inside the body are caught at
//! the task boundary and surface as [`TaskError::Panic`] — the analogue
//! of a C++ task throwing.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::error::{TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::runtime_handle::Runtime;

/// Conversion of task-body return values into `TaskResult`.
///
/// Implemented for plain values (`T`) and for `Result<T, E>` where the
/// error converts into [`TaskError`], so infallible tasks need no
/// boilerplate.
pub trait IntoTaskResult<T> {
    fn into_task_result(self) -> TaskResult<T>;
}

impl<T, E: Into<TaskError>> IntoTaskResult<T> for Result<T, E> {
    fn into_task_result(self) -> TaskResult<T> {
        self.map_err(Into::into)
    }
}

macro_rules! impl_into_task_result_value {
    ($($t:ty),*) => {$(
        impl IntoTaskResult<$t> for $t {
            fn into_task_result(self) -> TaskResult<$t> { Ok(self) }
        }
    )*};
}

impl_into_task_result_value!(
    (), bool, i8, i16, i32, i64, i128, isize, u8, u16, u32, u64, u128, usize, f32, f64, String
);

impl<T> IntoTaskResult<Vec<T>> for Vec<T> {
    fn into_task_result(self) -> TaskResult<Vec<T>> {
        Ok(self)
    }
}

/// Run `f` catching panics, converting them to [`TaskError::Panic`].
pub fn run_task_body<T, R, F>(f: F) -> TaskResult<T>
where
    F: FnOnce() -> R,
    R: IntoTaskResult<T>,
{
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r.into_task_result(),
        // NB: `&*payload`, not `&payload` — the latter would unsize the
        // Box itself into `dyn Any` and every downcast would miss.
        Err(payload) => Err(TaskError::Panic(panic_message(&*payload))),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// `hpx::async` — schedule `f` on the runtime, returning a future.
pub fn async_<T, R, F>(rt: &Runtime, f: F) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: FnOnce() -> R + Send + 'static,
{
    let (p, fut) = Promise::new();
    rt.pool().spawn_job(Box::new(move || {
        p.set_result(run_task_body(f));
    }));
    fut
}

/// `hpx::dataflow` — schedule `f(values)` once every future in `deps`
/// holds a value. If any dependency failed, `f` does not run and the
/// result carries [`TaskError::DependencyFailed`].
///
/// Dependency tracking is lock-free end to end: the underlying
/// [`when_all_results`](crate::future::when_all_results) join costs one
/// atomic decrement per completing dependency, and attaching to /
/// resolving the futures involved takes no mutex (see
/// `docs/ARCHITECTURE.md`, "Hot paths").
pub fn dataflow<T, U, R, F>(rt: &Runtime, f: F, deps: Vec<Future<T>>) -> Future<U>
where
    T: Clone + Send + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: FnOnce(Vec<T>) -> R + Send + 'static,
{
    let rt = rt.clone();
    let (p, fut) = Promise::new();
    crate::future::when_all_results(deps).on_ready(move |r| {
        match r.as_ref().map(|results| crate::future::collapse_results(results)) {
            Ok(Ok(values)) => {
                rt.pool().spawn_job(Box::new(move || {
                    p.set_result(run_task_body(move || f(values)));
                }));
            }
            Ok(Err(e)) => p.set_error(e),
            Err(e) => p.set_error(e.clone()),
        }
    });
    fut
}

/// Variant of [`dataflow`] whose body receives per-dependency
/// `TaskResult`s instead of failing wholesale — the building block the
/// resilient dataflow variants use to decide replay on *dependency*
/// content rather than collapse.
pub fn dataflow_results<T, U, R, F>(rt: &Runtime, f: F, deps: Vec<Future<T>>) -> Future<U>
where
    T: Clone + Send + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: FnOnce(Vec<TaskResult<T>>) -> R + Send + 'static,
{
    let rt = rt.clone();
    let (p, fut) = Promise::new();
    crate::future::when_all_results(deps).on_ready(move |r| match r {
        Ok(results) => {
            let results = results.clone();
            rt.pool().spawn_job(Box::new(move || {
                p.set_result(run_task_body(move || f(results)));
            }));
        }
        Err(e) => p.set_error(e.clone()),
    });
    fut
}

/// `hpx::async(exec, f)` — launch `f` through an executor: the call site
/// carries no policy; resiliency (replay, replication, validation,
/// adaptive budgets) comes entirely from the executor passed in. See
/// [`crate::resilience::executor`] for the available decorators.
///
/// ```
/// use rhpx::resilience::executor::ReplayExecutor;
/// use rhpx::{async_on, Runtime};
///
/// let rt = Runtime::builder().workers(2).build();
/// let exec = ReplayExecutor::new(rt.executor(), 3);
/// let f = async_on(&exec, || 5i32);
/// assert_eq!(f.get(), Ok(5));
/// ```
pub fn async_on<EX, T, R, F>(exec: &EX, f: F) -> Future<T>
where
    EX: crate::resilience::executor::ResilientExecutor,
    T: Clone + Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
{
    exec.spawn(f)
}

/// `hpx::dataflow(exec, f, deps)` — dataflow through an executor: runs
/// `f` over the dependency values once all of `deps` are ready, with the
/// body launched under the executor's policy.
///
/// ```
/// use rhpx::resilience::executor::ReplayExecutor;
/// use rhpx::{async_on, dataflow_on, Runtime};
///
/// let rt = Runtime::builder().workers(2).build();
/// let exec = ReplayExecutor::new(rt.executor(), 3);
/// let a = async_on(&exec, || 2i64);
/// let b = async_on(&exec, || 3i64);
/// let sum = dataflow_on(&exec, |v: &[i64]| v.iter().sum::<i64>(), vec![a, b]);
/// assert_eq!(sum.get(), Ok(5));
/// ```
pub fn dataflow_on<EX, T, U, R, F>(exec: &EX, f: F, deps: Vec<Future<T>>) -> Future<U>
where
    EX: crate::resilience::executor::ResilientExecutor,
    T: Clone + Send + Sync + 'static,
    U: Clone + Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    exec.dataflow(f, deps)
}

/// Fire-and-forget spawn (`hpx::apply`): no future is returned.
pub fn apply<F>(rt: &Runtime, f: F)
where
    F: FnOnce() + Send + 'static,
{
    rt.pool().spawn_job(Box::new(move || {
        // Swallow panics: an applied task has no observer.
        let _ = catch_unwind(AssertUnwindSafe(f));
    }));
}

/// Bundle used by resilient dataflow: shared, immutable dependency values.
pub type DepValues<T> = Arc<Vec<T>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime_handle::Runtime;

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn async_returns_value() {
        let rt = rt();
        let f = async_(&rt, || 21 * 2);
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn async_propagates_app_error() {
        let rt = rt();
        let f: Future<i32> = async_(&rt, || -> Result<i32, TaskError> {
            Err(TaskError::App("fail".into()))
        });
        assert_eq!(f.get(), Err(TaskError::App("fail".to_string())));
    }

    #[test]
    fn async_catches_panic() {
        let rt = rt();
        let f: Future<i32> = async_(&rt, || -> i32 { panic!("kaboom") });
        match f.get() {
            Err(TaskError::Panic(m)) => assert!(m.contains("kaboom"), "payload: {m}"),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn dataflow_runs_after_deps() {
        let rt = rt();
        let a = async_(&rt, || 1i64);
        let b = async_(&rt, || 2i64);
        let c = dataflow(&rt, |vals| vals.iter().sum::<i64>(), vec![a, b]);
        assert_eq!(c.get(), Ok(3));
    }

    #[test]
    fn dataflow_skips_body_on_failed_dep() {
        let rt = rt();
        let a = async_(&rt, || 1i64);
        let b: Future<i64> = async_(&rt, || -> Result<i64, TaskError> { Err("dead".into()) });
        let c = dataflow(
            &rt,
            |_vals| -> i64 { unreachable!("body must not run") },
            vec![a, b],
        );
        match c.get() {
            Err(TaskError::DependencyFailed(_)) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn nested_get_inside_task_does_not_deadlock() {
        // Even on a single worker: the inner get() helps run the inner task.
        let rt = Runtime::builder().workers(1).build();
        let rt2 = rt.clone();
        let outer = async_(&rt, move || {
            let inner = async_(&rt2, || 5i32);
            inner.get().unwrap() + 1
        });
        assert_eq!(outer.get(), Ok(6));
    }

    #[test]
    fn deep_dataflow_chain() {
        let rt = rt();
        let mut f = async_(&rt, || 0i64);
        for _ in 0..100 {
            f = dataflow(&rt, |v| v[0] + 1, vec![f]);
        }
        assert_eq!(f.get(), Ok(100));
    }
}
