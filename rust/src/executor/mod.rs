//! Executors — pluggable launch policies for the parallel algorithms.
//!
//! The paper's future work anticipates "special executors that will
//! manage the aspects of resiliency and task distribution across nodes".
//! The [`Executor`] trait here is the *algorithm-facing* face of that
//! idea: [`crate::algorithms`] is written once against it and gains
//! resiliency by executor choice. Since the decorator subsystem landed
//! ([`crate::resilience::executor`]), every resilient executor in this
//! module is a thin delegate over those decorators — [`ReplayExecutor`]
//! wraps `ReplayExecutor<PoolExecutor>`, [`DistributedReplayExecutor`]
//! wraps `ReplayExecutor<ClusterExecutor>` — so the replay/replicate
//! semantics live in exactly one place.
//!
//! ```
//! use rhpx::executor::{Executor, ReplayExecutor};
//! use rhpx::Runtime;
//!
//! let rt = Runtime::builder().workers(2).build();
//! let ex = ReplayExecutor::new(&rt, 3);
//! assert_eq!(ex.execute(|| Ok(5i32)).get(), Ok(5));
//! ```

use std::sync::Arc;

use crate::distributed::{Cluster, ClusterExecutor};
use crate::error::TaskResult;
use crate::future::Future;
use crate::resilience::executor::{
    PoolExecutor, ReplayExecutor as ReplayDecorator, ReplicateExecutor as ReplicateDecorator,
    ResilientExecutor,
};
use crate::resilience::Voter;
use crate::runtime_handle::Runtime;

/// A launch policy. Bodies are `Fn` (re-runnable) because resilient
/// policies may need to execute them more than once.
pub trait Executor: Clone + Send + Sync + 'static {
    /// Launch `f` under this executor's policy.
    fn execute<T, F>(&self, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        F: Fn() -> TaskResult<T> + Send + Sync + 'static;

    /// Parallelism hint used by algorithms for chunking.
    fn concurrency(&self) -> usize;
}

/// Plain `async_` launches — no resiliency (the baseline policy).
#[derive(Clone)]
pub struct PlainExecutor {
    rt: Runtime,
}

impl PlainExecutor {
    pub fn new(rt: &Runtime) -> Self {
        PlainExecutor { rt: rt.clone() }
    }
}

impl Executor for PlainExecutor {
    fn execute<T, F>(&self, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        F: Fn() -> TaskResult<T> + Send + Sync + 'static,
    {
        crate::api::async_(&self.rt, f)
    }

    fn concurrency(&self) -> usize {
        self.rt.workers()
    }
}

/// Every launch is an `async_replay(n, …)` (§IV-A as a policy); delegates
/// to the [`crate::resilience::executor`] replay decorator over the
/// runtime's pool.
#[derive(Clone)]
pub struct ReplayExecutor {
    inner: ReplayDecorator<PoolExecutor>,
}

impl ReplayExecutor {
    pub fn new(rt: &Runtime, n: usize) -> Self {
        ReplayExecutor { inner: ReplayDecorator::new(PoolExecutor::new(rt), n) }
    }
}

impl Executor for ReplayExecutor {
    fn execute<T, F>(&self, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        F: Fn() -> TaskResult<T> + Send + Sync + 'static,
    {
        self.inner.spawn(f)
    }

    fn concurrency(&self) -> usize {
        self.inner.concurrency()
    }
}

/// Every launch is replicated `n`× (§IV-B as a policy), with an optional
/// voting function for consensus over the replicas; delegates to the
/// replicate decorator.
#[derive(Clone)]
pub struct ReplicateExecutor<T: Clone + Send + 'static> {
    inner: ReplicateDecorator<PoolExecutor>,
    voter: Option<Voter<T>>,
}

impl<T: Clone + Send + 'static> ReplicateExecutor<T> {
    pub fn new(rt: &Runtime, n: usize) -> Self {
        ReplicateExecutor {
            inner: ReplicateDecorator::new(PoolExecutor::new(rt), n),
            voter: None,
        }
    }

    pub fn with_vote(rt: &Runtime, n: usize, voter: Voter<T>) -> Self {
        ReplicateExecutor {
            inner: ReplicateDecorator::new(PoolExecutor::new(rt), n),
            voter: Some(voter),
        }
    }

    /// Launch under this policy (typed executor: `T` is fixed by the
    /// voter, so this is an inherent method rather than the trait).
    pub fn execute<F>(&self, f: F) -> Future<T>
    where
        F: Fn() -> TaskResult<T> + Send + Sync + 'static,
    {
        match &self.voter {
            None => self.inner.spawn(f),
            Some(v) => {
                let v = Arc::clone(v);
                self.inner.spawn_vote(move |b: &[T]| v(b), f)
            }
        }
    }

    pub fn concurrency(&self) -> usize {
        ResilientExecutor::concurrency(&self.inner)
    }
}

/// Launches are replayed *across localities* of a cluster — the
/// distributed executor of the paper's future work, realized as the
/// replay decorator over a [`ClusterExecutor`] (each retry routes to the
/// next locality).
#[derive(Clone)]
pub struct DistributedReplayExecutor {
    inner: ReplayDecorator<ClusterExecutor>,
}

impl DistributedReplayExecutor {
    pub fn new(cluster: &Cluster, n: usize) -> Self {
        DistributedReplayExecutor {
            inner: ReplayDecorator::new(ClusterExecutor::new(cluster), n),
        }
    }
}

impl Executor for DistributedReplayExecutor {
    fn execute<T, F>(&self, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        F: Fn() -> TaskResult<T> + Send + Sync + 'static,
    {
        self.inner.spawn(f)
    }

    fn concurrency(&self) -> usize {
        self.inner.concurrency()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agas::LocalityId;
    use crate::distributed::NetworkConfig;
    use crate::resilience::vote_majority;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn plain_executor_runs() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        assert_eq!(ex.execute(|| Ok(5i32)).get(), Ok(5));
        assert_eq!(ex.concurrency(), 2);
    }

    #[test]
    fn replay_executor_retries() {
        let rt = rt();
        let ex = ReplayExecutor::new(&rt, 4);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.execute(move || -> TaskResult<i32> {
            if c.fetch_add(1, Ordering::SeqCst) < 2 {
                Err("flaky".into())
            } else {
                Ok(1)
            }
        });
        assert_eq!(f.get(), Ok(1));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replicate_executor_votes() {
        let rt = rt();
        let ex = ReplicateExecutor::with_vote(&rt, 3, Arc::new(vote_majority));
        let i = Arc::new(AtomicUsize::new(0));
        let ic = Arc::clone(&i);
        let f = ex.execute(move || {
            Ok(if ic.fetch_add(1, Ordering::SeqCst) == 0 { -1i64 } else { 9 })
        });
        assert_eq!(f.get(), Ok(9));
    }

    #[test]
    fn distributed_executor_survives_dead_node() {
        let cl = Cluster::new(3, 1, NetworkConfig::default());
        cl.kill(LocalityId(0));
        let ex = DistributedReplayExecutor::new(&cl, 3);
        assert_eq!(ex.execute(|| Ok(7u8)).get(), Ok(7));
        assert_eq!(ex.concurrency(), 3);
    }
}
