//! Heartbeat failure detection over the simulated cluster.
//!
//! The distributed executors in this crate learn about dead localities
//! only when a task routed there fails. Real deployments (MPI-ULFM,
//! SLURM health checks) run an out-of-band failure detector instead;
//! this module provides one: a monitor thread heartbeats every locality
//! through the active-message layer, maintains a membership view, and
//! notifies subscribers on state transitions — so schedulers can avoid
//! routing to dead nodes *before* burning a replay attempt.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::agas::LocalityId;
use crate::error::TaskError;
use crate::future::{channel, Receiver, Sender};

use super::locality::Cluster;

/// A membership transition observed by the detector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipEvent {
    Died(LocalityId),
    Rejoined(LocalityId),
}

/// Snapshot of the detector's view.
#[derive(Debug, Clone)]
pub struct MembershipView {
    /// Localities believed alive.
    pub alive: Vec<LocalityId>,
    /// Localities believed dead.
    pub dead: Vec<LocalityId>,
    /// Heartbeat rounds completed.
    pub rounds: u64,
}

/// A recovery hook: runs on the detector thread for every membership
/// transition.
type EventHook = Arc<dyn Fn(MembershipEvent) + Send + Sync>;

struct DetectorState {
    alive: HashMap<LocalityId, bool>,
    rounds: u64,
    subscribers: Vec<Sender<MembershipEvent>>,
    hooks: Vec<EventHook>,
}

/// Heartbeat-based failure detector for a [`Cluster`].
pub struct FailureDetector {
    state: Arc<Mutex<DetectorState>>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl FailureDetector {
    /// Start monitoring `cluster`, heartbeating every `period`.
    pub fn start(cluster: &Cluster, period: Duration) -> Self {
        let state = Arc::new(Mutex::new(DetectorState {
            alive: (0..cluster.len()).map(|i| (LocalityId(i), true)).collect(),
            rounds: 0,
            subscribers: Vec::new(),
            hooks: Vec::new(),
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let cluster = cluster.clone();
        let st = Arc::clone(&state);
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("rhpx-failure-detector".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    let mut events = Vec::new();
                    // Heartbeat: a trivial task per locality. A dead
                    // locality rejects it at dispatch.
                    for i in 0..cluster.len() {
                        let id = LocalityId(i);
                        let beat = cluster
                            .run_on(id, |_| Ok::<_, TaskError>(()))
                            .get()
                            .is_ok();
                        let mut g = st.lock().unwrap();
                        let prev = g.alive.insert(id, beat).unwrap_or(true);
                        if prev != beat {
                            events.push(if beat {
                                MembershipEvent::Rejoined(id)
                            } else {
                                MembershipEvent::Died(id)
                            });
                        }
                    }
                    let hooks: Vec<EventHook> = {
                        let mut g = st.lock().unwrap();
                        g.rounds += 1;
                        for ev in &events {
                            for sub in &g.subscribers {
                                sub.send(*ev);
                            }
                        }
                        // Clone the hook list only when something fired:
                        // the steady (no-event) heartbeat allocates
                        // nothing.
                        if events.is_empty() { Vec::new() } else { g.hooks.clone() }
                    };
                    // Hooks run outside the state lock so a recovery
                    // action may call back into the detector (or the
                    // cluster) without deadlocking.
                    for ev in &events {
                        for hook in &hooks {
                            hook(*ev);
                        }
                    }
                    std::thread::sleep(period);
                }
            })
            .expect("spawn failure detector");
        FailureDetector { state, stop, handle: Some(handle) }
    }

    /// Current membership view.
    pub fn view(&self) -> MembershipView {
        let g = self.state.lock().unwrap();
        let mut alive: Vec<LocalityId> =
            g.alive.iter().filter(|(_, a)| **a).map(|(id, _)| *id).collect();
        let mut dead: Vec<LocalityId> =
            g.alive.iter().filter(|(_, a)| !**a).map(|(id, _)| *id).collect();
        alive.sort();
        dead.sort();
        MembershipView { alive, dead, rounds: g.rounds }
    }

    /// True if the detector currently believes `id` is alive.
    pub fn is_alive(&self, id: LocalityId) -> bool {
        *self.state.lock().unwrap().alive.get(&id).unwrap_or(&false)
    }

    /// Subscribe to membership transitions (death/rejoin events).
    pub fn subscribe(&self) -> Receiver<MembershipEvent> {
        let (tx, rx) = channel();
        self.state.lock().unwrap().subscribers.push(tx);
        rx
    }

    /// Register a recovery hook: `f` runs on the detector thread for
    /// every membership transition (the ORNL resilience-pattern split —
    /// this detector *detects*, the hook is where a *recovery* action
    /// such as re-provisioning or draining a locality attaches). Hooks
    /// run outside the detector's state lock, so they may inspect the
    /// view or act on the cluster; heartbeating pauses until they
    /// return, so keep them short.
    pub fn on_event<F>(&self, f: F)
    where
        F: Fn(MembershipEvent) + Send + Sync + 'static,
    {
        self.state.lock().unwrap().hooks.push(Arc::new(f));
    }

    /// Block until at least `n` heartbeat rounds have completed.
    pub fn wait_rounds(&self, n: u64) {
        while self.state.lock().unwrap().rounds < n {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for FailureDetector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::NetworkConfig;

    #[test]
    fn detects_death_and_rejoin() {
        let cl = Cluster::new(3, 1, NetworkConfig::default());
        let det = FailureDetector::start(&cl, Duration::from_millis(1));
        det.wait_rounds(2);
        assert_eq!(det.view().alive.len(), 3);
        assert!(det.is_alive(LocalityId(1)));

        let events = det.subscribe();
        cl.kill(LocalityId(1));
        let base = det.view().rounds;
        det.wait_rounds(base + 2);
        assert!(!det.is_alive(LocalityId(1)));
        assert_eq!(det.view().dead, vec![LocalityId(1)]);
        assert_eq!(events.recv().get(), Ok(MembershipEvent::Died(LocalityId(1))));

        cl.revive(LocalityId(1));
        let base = det.view().rounds;
        det.wait_rounds(base + 2);
        assert!(det.is_alive(LocalityId(1)));
        assert_eq!(
            events.recv().get(),
            Ok(MembershipEvent::Rejoined(LocalityId(1)))
        );
    }

    #[test]
    fn recovery_hook_can_heal_the_cluster() {
        // A hook that revives any locality the detector declares dead:
        // the detector must subsequently observe the rejoin — the
        // smallest possible detector → recovery → rejoin loop.
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        let det = FailureDetector::start(&cl, Duration::from_millis(1));
        det.wait_rounds(1);
        let healer = cl.clone();
        det.on_event(move |ev| {
            if let MembershipEvent::Died(id) = ev {
                healer.revive(id);
            }
        });
        let events = det.subscribe();
        cl.kill(LocalityId(0));
        assert_eq!(events.recv().get(), Ok(MembershipEvent::Died(LocalityId(0))));
        assert_eq!(
            events.recv().get(),
            Ok(MembershipEvent::Rejoined(LocalityId(0))),
            "the hook's revive must be observed as a rejoin"
        );
        assert!(cl.locality(LocalityId(0)).is_alive());
    }

    #[test]
    fn detector_shuts_down_cleanly() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        let det = FailureDetector::start(&cl, Duration::from_millis(1));
        det.wait_rounds(1);
        drop(det); // must join without hanging
    }
}
