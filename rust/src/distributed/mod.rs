//! Distributed resiliency over simulated localities (§Future-Work,
//! implemented).
//!
//! The paper's future work: "extend the presented resiliency facilities
//! to the distributed case … by introducing special executors that will
//! manage the aspects of resiliency and task distribution across nodes."
//!
//! Real multi-node hardware is not available in this testbed, so a
//! [`Cluster`] simulates HPX localities in-process: each locality owns an
//! independent scheduler pool and a mailbox pumped by an active-message
//! thread (HPX component (3): "an active-message networking layer"), with
//! configurable per-message latency modelling the interconnect. A
//! locality can be *killed* — its mailbox keeps accepting messages but
//! every task routed to it fails, the way ULFM surfaces dead ranks —
//! which is what the distributed executors recover from:
//!
//! * [`async_replay_distributed`] — replay across localities: each retry
//!   is routed to the *next* locality (local failure, local recovery,
//!   no global rollback);
//! * [`async_replicate_distributed`] — replicas fan out to distinct
//!   localities so a dead node cannot take out more than one replica.
//!
//! The same routing is available through the decorator subsystem:
//! [`ClusterExecutor`] is a [`crate::resilience::executor::TaskLauncher`]
//! over the cluster, so wrapping it in a `ReplayExecutor` or
//! `ReplicateExecutor` gives executor-routed distributed resilience —
//! replay walks the localities, replicate fans replicas out across them
//! (this is how [`crate::executor::DistributedReplayExecutor`] is built,
//! and how the §V-B stencil driver runs distributed: see
//! [`crate::stencil::StencilParams::cluster`]).
//!
//! Fault injection is scripted, not sampled: a [`FaultSchedule`] (parsed
//! from `kill=STEP@LOC,…`) kills localities at deterministic points of a
//! driver's step counter, so the recovered-vs-poisoned outcome of a
//! survival experiment replays run over run. An
//! out-of-band [`FailureDetector`] heartbeats the cluster and exposes
//! membership transitions to channels ([`FailureDetector::subscribe`])
//! and recovery hooks ([`FailureDetector::on_event`]).
//!
//! Values crossing localities require `Clone` (the in-process stand-in
//! for serializability over a real wire).
//!
//! The simulation is no longer the only substrate: [`proc`] promotes
//! localities to real OS processes (`rhpx worker` children speaking the
//! framed [`crate::serve::protocol`] over TCP), where failure detection
//! is missed heartbeats ([`HeartbeatMonitor`]) and fault injection is a
//! literal `SIGKILL` of a child PID. The in-process [`Cluster`] remains
//! the deterministic test substrate; [`ProcCluster`] is the honest one.

pub mod detector;
mod locality;
pub mod proc;

pub use detector::{FailureDetector, MembershipEvent, MembershipView};
pub use locality::{Cluster, Locality, NetworkConfig};
pub use proc::{
    HeartbeatMonitor, ProcCluster, ProcExec, ProcMirrorStore, ProcSpec, RemoteWorkload,
    WorkerConfig,
};

use std::sync::Arc;

use crate::agas::LocalityId;
use crate::error::{ResilienceError, TaskError, TaskResult};
use crate::future::{when_all_results, Future, Promise};
use crate::resilience::Voter;

// ---------------------------------------------------------------------
// Deterministic fault schedules (scripted locality kills)
// ---------------------------------------------------------------------

/// One scheduled locality kill: at global step `step` (the interpretation
/// of "step" belongs to the driver running the schedule — the stencil
/// driver counts task launches), locality `loc` dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillEvent {
    /// 0-based step at which the kill fires (inclusive: the kill is
    /// applied *before* the work of that step is issued).
    pub step: usize,
    pub loc: LocalityId,
}

/// A scripted fault schedule: a sorted list of [`KillEvent`]s applied
/// to a [`Cluster`] as a driver advances through its steps. Parsed from
/// the CLI's `kill=STEP@LOC[,kill=STEP@LOC…]` syntax. Each kill fires
/// at the same driver step every run, so the *outcome* of a survival
/// experiment (recovered vs. poisoned, which locality died and when) is
/// replayable and regression-testable; the exact set of attempts that
/// observe the dead locality still depends on execution timing, since
/// tasks issued before the kill execute asynchronously.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    /// Sorted by `step`.
    events: Vec<KillEvent>,
    /// Index of the first event not yet applied.
    fired: usize,
}

impl FaultSchedule {
    /// A schedule from explicit events (sorted internally).
    pub fn new(mut events: Vec<KillEvent>) -> Self {
        events.sort_by_key(|e| e.step);
        FaultSchedule { events, fired: 0 }
    }

    /// Parse `kill=STEP@LOC[,kill=STEP@LOC…]`. Every event must name a
    /// locality below `localities`; a locality may die at most once (a
    /// second kill of a dead locality can never be observed, so it is
    /// rejected as a schedule typo rather than silently ignored).
    ///
    /// ```
    /// use rhpx::distributed::FaultSchedule;
    ///
    /// let s = FaultSchedule::parse("kill=10@2,kill=3@1", 4).unwrap();
    /// assert_eq!(s.events().len(), 2);
    /// assert_eq!(s.events()[0].step, 3); // sorted by step
    /// assert!(FaultSchedule::parse("kill=10@9", 4).is_err()); // out of range
    /// ```
    pub fn parse(spec: &str, localities: usize) -> Result<FaultSchedule, String> {
        let mut events: Vec<KillEvent> = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let rest = part.strip_prefix("kill=").ok_or_else(|| {
                format!("bad fault event {part:?} (expected kill=STEP@LOC)")
            })?;
            let (step, loc) = rest.split_once('@').ok_or_else(|| {
                format!("bad fault event {part:?} (expected kill=STEP@LOC)")
            })?;
            let step: usize = step
                .parse()
                .map_err(|_| format!("kill step {step:?} is not a number"))?;
            let loc: usize = loc
                .parse()
                .map_err(|_| format!("kill locality {loc:?} is not a number"))?;
            if loc >= localities {
                return Err(format!(
                    "kill locality {loc} out of range (localities={localities})"
                ));
            }
            if events.iter().any(|e| e.loc.0 == loc) {
                return Err(format!("duplicate kill for locality {loc}"));
            }
            events.push(KillEvent { step, loc: LocalityId(loc) });
        }
        Ok(FaultSchedule::new(events))
    }

    /// The scheduled events, sorted by step.
    pub fn events(&self) -> &[KillEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Apply every not-yet-fired event with `event.step <= step` to
    /// `cluster`; returns the events fired now (in step order). Called
    /// once per driver step; events whose step is never reached simply
    /// never fire.
    pub fn advance(&mut self, step: usize, cluster: &Cluster) -> Vec<KillEvent> {
        let mut fired = Vec::new();
        while self.fired < self.events.len() && self.events[self.fired].step <= step {
            let ev = self.events[self.fired];
            cluster.kill(ev.loc);
            fired.push(ev);
            self.fired += 1;
        }
        fired
    }
}

/// Declarative description of a simulated cluster plus its fault
/// schedule — what `rhpx stencil --cluster LOCALITIES[:kill=STEP@LOC,…]`
/// parses into, and what [`ClusterSpec::build`] turns into a live
/// [`Cluster`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterSpec {
    pub localities: usize,
    /// Scheduler threads per locality.
    pub workers_per_locality: usize,
    /// One-way active-message latency in microseconds.
    pub latency_us: u64,
    pub schedule: FaultSchedule,
}

impl ClusterSpec {
    /// A fault-free spec with 1 worker per locality and loopback latency.
    pub fn new(localities: usize) -> Self {
        ClusterSpec {
            localities: localities.max(1),
            workers_per_locality: 1,
            latency_us: 0,
            schedule: FaultSchedule::default(),
        }
    }

    /// Parse `LOCALITIES[:kill=STEP@LOC,…]`.
    ///
    /// ```
    /// use rhpx::distributed::ClusterSpec;
    ///
    /// let spec = ClusterSpec::parse("4:kill=10@2").unwrap();
    /// assert_eq!(spec.localities, 4);
    /// assert_eq!(spec.schedule.events()[0].step, 10);
    /// assert!(ClusterSpec::parse("0").is_err());
    /// assert!(ClusterSpec::parse("4:").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<ClusterSpec, String> {
        let (count, sched) = match s.split_once(':') {
            Some((c, rest)) => (c, Some(rest)),
            None => (s, None),
        };
        let localities: usize = count
            .parse()
            .ok()
            .filter(|n| *n >= 1)
            .ok_or_else(|| format!("bad locality count {count:?} (expected >= 1)"))?;
        let schedule = match sched {
            Some(rest) => FaultSchedule::parse(rest, localities)?,
            None => FaultSchedule::default(),
        };
        Ok(ClusterSpec { schedule, ..ClusterSpec::new(localities) })
    }

    /// Spin up the described cluster (schedule not yet applied — drivers
    /// advance it themselves so kills land at deterministic points of
    /// *their* step counter).
    pub fn build(&self) -> Cluster {
        Cluster::new(
            self.localities,
            self.workers_per_locality,
            NetworkConfig { latency_us: self.latency_us },
        )
    }
}

/// A distributable task body: runs on whichever locality it is routed
/// to; receives that locality so it can interact with local services
/// (AGAS, local spawns, …).
pub type DistBody<T> = Arc<dyn Fn(&Locality) -> TaskResult<T> + Send + Sync>;

/// A [`TaskLauncher`](crate::resilience::executor::TaskLauncher) over
/// the cluster — the cluster-backed base the resilience decorators wrap.
/// Standalone submissions are routed round-robin; decorated launches use
/// the placement-token protocol, so each launch's attempts/replicas land
/// on *successive* localities (`token + seq`): a retry is guaranteed to
/// leave the locality that just failed, and `n ≤ len` replicas occupy
/// `n` distinct localities, even when many launches interleave on the
/// shared round-robin counter.
#[derive(Clone)]
pub struct ClusterExecutor {
    cluster: Cluster,
    /// Route standalone submissions over live localities only (the
    /// membership-consuming placement mode of the checkpoint strategy;
    /// see [`ClusterExecutor::alive_routed`]).
    alive_only: bool,
}

impl ClusterExecutor {
    pub fn new(cluster: &Cluster) -> Self {
        ClusterExecutor { cluster: cluster.clone(), alive_only: false }
    }

    /// A launcher that places standalone submissions on *live*
    /// localities only, consuming the membership view the way a
    /// [`FailureDetector`]-driven scheduler would. This is what the
    /// checkpoint/restart strategy runs over: unlike replay (which
    /// absorbs a dead-locality attempt as a retry) it has no per-task
    /// retry to hide behind, so routing to a known corpse would poison a
    /// task per launch. In this mode a kill racing the placement
    /// re-routes the submission to a survivor instead of rejecting it.
    /// Decorated launches run over [`ClusterExecutor::new`], which keeps
    /// the full ring so the replay/replicate placement guarantees are
    /// unchanged.
    pub fn alive_routed(cluster: &Cluster) -> Self {
        ClusterExecutor { cluster: cluster.clone(), alive_only: true }
    }

    /// The cluster submissions are routed over.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }
}

impl crate::resilience::executor::TaskLauncher for ClusterExecutor {
    fn submit<T: Send + 'static>(
        &self,
        body: crate::resilience::executor::TaskFn<T>,
    ) -> Future<T> {
        // Tracked submission: the task carries a lineage record while
        // queued, so a kill drains it onto a survivor instead of losing
        // it (resilient work stealing). On the full ring, dead-at-submit
        // rejects, preserving the failure signal the decorators recover
        // from; in alive-only mode there is no decorator to absorb a
        // rejection, so a kill racing the placement re-routes to a
        // survivor instead.
        if self.alive_only {
            let target = self.cluster.next_alive_target();
            self.cluster
                .run_on_resilient_routed(target, None, Arc::new(move |_loc: &Locality| body()))
        } else {
            let target = self.cluster.next_target();
            self.cluster.run_on_resilient(target, None, Arc::new(move |_loc: &Locality| body()))
        }
    }

    fn placement_token(&self) -> usize {
        self.cluster.next_target().0
    }

    fn submit_seq<T: Send + 'static>(
        &self,
        body: crate::resilience::executor::TaskFn<T>,
        token: usize,
        seq: usize,
    ) -> Future<T> {
        let target = LocalityId((token + seq) % self.cluster.len());
        if self.alive_only {
            // Sequence placement is advisory under live-only routing: a
            // dead seq-target re-routes rather than poisoning the slot.
            self.cluster
                .run_on_resilient_routed(target, None, Arc::new(move |_loc: &Locality| body()))
        } else {
            self.cluster.run_on_resilient(target, None, Arc::new(move |_loc: &Locality| body()))
        }
    }

    fn parallelism(&self) -> usize {
        self.cluster.len()
    }

    fn base_label(&self) -> String {
        format!("cluster({})", self.cluster.len())
    }
}

/// Replay across localities: up to `n` total attempts, each retry routed
/// to the next locality in the ring (skipping nothing — a retry landing
/// on another dead locality simply burns an attempt, as on real systems
/// until a failure detector prunes membership).
pub fn async_replay_distributed<T: Clone + Send + 'static>(
    cluster: &Cluster,
    n: usize,
    body: DistBody<T>,
) -> Future<T> {
    let (p, fut) = Promise::new();
    let start = cluster.next_target();
    attempt_on(cluster.clone(), p, body, n.max(1), 1, start);
    fut
}

fn attempt_on<T: Clone + Send + 'static>(
    cluster: Cluster,
    promise: Promise<T>,
    body: DistBody<T>,
    n: usize,
    attempt: usize,
    target: LocalityId,
) {
    let body2 = Arc::clone(&body);
    let inner = cluster.run_on(target, move |loc| body2(loc));
    inner.on_ready(move |r| match r {
        Ok(v) => promise.set_value(v.clone()),
        Err(e) => {
            if attempt < n {
                let next = cluster.next_locality(target);
                attempt_on(cluster.clone(), promise, body, n, attempt + 1, next);
            } else {
                promise.set_error(
                    ResilienceError::Exhausted { attempts: attempt, last: e.clone() }.into(),
                );
            }
        }
    });
}

/// Replicate across localities: `n` replicas, each on a distinct
/// locality (round-robin when `n` exceeds the cluster size). With
/// `vote = None` the lowest-indexed successful replica wins; with a
/// voter, consensus is built over all successful results.
pub fn async_replicate_distributed<T: Clone + Send + 'static>(
    cluster: &Cluster,
    n: usize,
    vote: Option<Voter<T>>,
    body: DistBody<T>,
) -> Future<T> {
    let n = n.max(1);
    let start = cluster.next_target().0;
    let futs: Vec<Future<T>> = (0..n)
        .map(|i| {
            let target = LocalityId((start + i) % cluster.len());
            let body = Arc::clone(&body);
            cluster.run_on(target, move |loc| body(loc))
        })
        .collect();
    when_all_results(futs).then(move |r| {
        let results = match r {
            Ok(results) => results,
            Err(e) => return Err(e.clone()),
        };
        let oks: Vec<T> = results.iter().filter_map(|x| x.as_ref().ok().cloned()).collect();
        if oks.is_empty() {
            let last = results
                .iter()
                .rev()
                .find_map(|x| x.as_ref().err().cloned())
                .unwrap_or(TaskError::App("no replica result".into()));
            return Err(ResilienceError::AllReplicasFailed { replicas: n, last }.into());
        }
        match &vote {
            None => Ok(oks[0].clone()),
            Some(v) => match v(&oks) {
                Some(winner) => Ok(winner),
                None => Err(ResilienceError::NoConsensus { candidates: oks.len() }.into()),
            },
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resilience::vote_majority;

    fn cluster(n: usize) -> Cluster {
        Cluster::new(n, 1, NetworkConfig::default())
    }

    #[test]
    fn run_on_executes_on_target_locality() {
        let cl = cluster(3);
        let f = cl.run_on(LocalityId(2), |loc| Ok::<_, TaskError>(loc.id().0));
        assert_eq!(f.get(), Ok(2));
    }

    #[test]
    fn dead_locality_fails_tasks() {
        let cl = cluster(2);
        cl.kill(LocalityId(1));
        let f = cl.run_on(LocalityId(1), |_| Ok::<_, TaskError>(1));
        assert!(f.get().is_err());
        cl.revive(LocalityId(1));
        let f = cl.run_on(LocalityId(1), |_| Ok::<_, TaskError>(1));
        assert_eq!(f.get(), Ok(1));
    }

    #[test]
    fn distributed_replay_survives_dead_node() {
        let cl = cluster(3);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        // Replay must walk the ring until it lands on locality 2.
        let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
        let f = async_replay_distributed(&cl, 5, body);
        assert_eq!(f.get(), Ok(2));
    }

    #[test]
    fn distributed_replay_exhausts_on_all_dead() {
        let cl = cluster(2);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
        let f = async_replay_distributed(&cl, 4, body);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::Exhausted { attempts: 4, .. }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn distributed_replicate_survives_minority_death() {
        let cl = cluster(3);
        cl.kill(LocalityId(1));
        let body: DistBody<i64> = Arc::new(|_| Ok(42));
        let f = async_replicate_distributed(&cl, 3, Some(Arc::new(vote_majority)), body);
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn distributed_replicate_all_dead_fails() {
        let cl = cluster(2);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        let body: DistBody<i64> = Arc::new(|_| Ok(1));
        let f = async_replicate_distributed(&cl, 2, None, body);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { replicas: 2, .. }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicas_fan_out_to_distinct_localities() {
        let cl = cluster(3);
        let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
        // With vote=None the first (lowest-index-launched) replica wins,
        // but all three ran on distinct localities; check by collecting.
        let futs: Vec<Future<usize>> = (0..3)
            .map(|i| {
                let b = Arc::clone(&body);
                cl.run_on(LocalityId(i), move |loc| b(loc))
            })
            .collect();
        let mut ids: Vec<usize> = futs.into_iter().map(|f| f.get().unwrap()).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn replay_decorator_over_cluster_walks_past_dead_localities() {
        use crate::resilience::executor::{ReplayExecutor, ResilientExecutor};
        let cl = cluster(3);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        // Fresh cluster: round-robin starts at 0, so the decorator's
        // retries must walk 0 (dead) → 1 (dead) → 2 (alive).
        let ex = ReplayExecutor::new(ClusterExecutor::new(&cl), 5);
        assert_eq!(ex.spawn(|| 7u8).get(), Ok(7));
        assert_eq!(ex.concurrency(), 3);
    }

    #[test]
    fn replay_decorator_concurrent_launches_each_walk_distinct_localities() {
        use crate::resilience::executor::{ReplayExecutor, ResilientExecutor};
        let cl = cluster(2);
        cl.kill(LocalityId(0));
        let ex = ReplayExecutor::new(ClusterExecutor::new(&cl), 2);
        // Many interleaved launches pop the shared round-robin counter
        // concurrently, but each launch's two attempts are placed at
        // token and token+1, so every launch covers both localities and
        // is guaranteed to reach the live one.
        let futs: Vec<_> = (0..16).map(|_| ex.spawn(|| 1u8)).collect();
        for f in futs {
            assert_eq!(f.get(), Ok(1));
        }
    }

    #[test]
    fn replicate_decorator_over_cluster_fans_out_and_votes() {
        use crate::resilience::executor::{ReplicateExecutor, ResilientExecutor};
        let cl = cluster(3);
        cl.kill(LocalityId(1));
        // Three replicas land on three distinct localities; the dead one
        // loses exactly one replica and the majority still agrees.
        let ex = ReplicateExecutor::new(ClusterExecutor::new(&cl), 3);
        let f = ex.spawn_vote(vote_majority, || 42i64);
        assert_eq!(f.get(), Ok(42));
        assert_eq!(ex.concurrency(), 3);
    }

    #[test]
    fn alive_routed_executor_never_places_on_a_corpse() {
        use crate::resilience::executor::TaskLauncher;
        let cl = cluster(3);
        cl.kill(LocalityId(1));
        let ex = ClusterExecutor::alive_routed(&cl);
        let futs: Vec<Future<usize>> = (0..12)
            .map(|_| ex.submit(Arc::new(|| Ok::<_, TaskError>(0usize))))
            .collect();
        for f in futs {
            assert_eq!(f.get(), Ok(0), "alive routing must avoid the dead locality");
        }
        assert_eq!(cl.locality(LocalityId(1)).tasks_rejected(), 0);
        // All dead: falls back to the plain ring and the attempt fails
        // like any other (no panic, no spin).
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(2));
        assert!(ex.submit(Arc::new(|| Ok::<_, TaskError>(0usize))).get().is_err());
    }

    #[test]
    fn fault_schedule_parses_sorts_and_validates() {
        let s = FaultSchedule::parse("kill=10@2,kill=3@1", 4).unwrap();
        assert_eq!(
            s.events(),
            &[
                KillEvent { step: 3, loc: LocalityId(1) },
                KillEvent { step: 10, loc: LocalityId(2) },
            ]
        );
        assert!(!s.is_empty());
        assert!(FaultSchedule::parse("", 4).is_err(), "empty event list");
        assert!(FaultSchedule::parse("kill=", 4).is_err(), "missing STEP@LOC");
        assert!(FaultSchedule::parse("kill=5", 4).is_err(), "missing @LOC");
        assert!(FaultSchedule::parse("kill=x@1", 4).is_err(), "non-numeric step");
        assert!(FaultSchedule::parse("kill=1@y", 4).is_err(), "non-numeric locality");
        assert!(FaultSchedule::parse("kill=1@4", 4).is_err(), "locality out of range");
        assert!(FaultSchedule::parse("die=1@0", 4).is_err(), "unknown event kind");
        assert!(
            FaultSchedule::parse("kill=1@0,kill=2@0", 4).is_err(),
            "duplicate locality"
        );
        assert!(
            FaultSchedule::parse("kill=1@0,", 4).is_err(),
            "trailing comma is a malformed (empty) event"
        );
    }

    #[test]
    fn cluster_spec_parses_count_and_schedule() {
        assert_eq!(ClusterSpec::parse("4").unwrap(), ClusterSpec::new(4));
        let spec = ClusterSpec::parse("4:kill=10@2").unwrap();
        assert_eq!(spec.localities, 4);
        assert_eq!(
            spec.schedule.events(),
            &[KillEvent { step: 10, loc: LocalityId(2) }]
        );
        assert!(ClusterSpec::parse("0").is_err(), "zero localities");
        assert!(ClusterSpec::parse("").is_err());
        assert!(ClusterSpec::parse("x").is_err());
        assert!(ClusterSpec::parse("4:").is_err(), "colon with no events");
        assert!(ClusterSpec::parse("4:kill=1@7").is_err(), "event out of range");
        assert_eq!(ClusterSpec::parse("2").unwrap().build().len(), 2);
    }

    #[test]
    fn fault_schedule_advance_fires_due_events_once() {
        let cl = cluster(3);
        let mut s = FaultSchedule::parse("kill=5@1,kill=2@0", 3).unwrap();
        assert!(s.advance(1, &cl).is_empty());
        assert_eq!(cl.alive_ids().len(), 3);
        // Step 2 fires the first kill…
        let fired = s.advance(2, &cl);
        assert_eq!(fired, vec![KillEvent { step: 2, loc: LocalityId(0) }]);
        assert!(!cl.locality(LocalityId(0)).is_alive());
        // …and does not re-fire it when the driver skips ahead.
        let fired = s.advance(9, &cl);
        assert_eq!(fired, vec![KillEvent { step: 5, loc: LocalityId(1) }]);
        assert_eq!(cl.alive_ids(), vec![LocalityId(2)]);
        assert!(s.advance(100, &cl).is_empty(), "schedule is exhausted");
    }

    #[test]
    fn agas_is_cluster_wide() {
        let cl = cluster(2);
        let gid = cl.agas().register(LocalityId(0), 7i64);
        let agas = cl.agas().clone();
        let f = cl.run_on(LocalityId(1), move |_| {
            agas.resolve::<i64>(gid)
                .map(|v| *v)
                .ok_or(TaskError::App("gid not found".into()))
        });
        assert_eq!(f.get(), Ok(7));
    }
}
