//! Distributed resiliency over simulated localities (§Future-Work,
//! implemented).
//!
//! The paper's future work: "extend the presented resiliency facilities
//! to the distributed case … by introducing special executors that will
//! manage the aspects of resiliency and task distribution across nodes."
//!
//! Real multi-node hardware is not available in this testbed, so a
//! [`Cluster`] simulates HPX localities in-process: each locality owns an
//! independent scheduler pool and a mailbox pumped by an active-message
//! thread (HPX component (3): "an active-message networking layer"), with
//! configurable per-message latency modelling the interconnect. A
//! locality can be *killed* — its mailbox keeps accepting messages but
//! every task routed to it fails, the way ULFM surfaces dead ranks —
//! which is what the distributed executors recover from:
//!
//! * [`async_replay_distributed`] — replay across localities: each retry
//!   is routed to the *next* locality (local failure, local recovery,
//!   no global rollback);
//! * [`async_replicate_distributed`] — replicas fan out to distinct
//!   localities so a dead node cannot take out more than one replica.
//!
//! Values crossing localities require `Clone` (the in-process stand-in
//! for serializability over a real wire).

pub mod detector;
mod locality;

pub use detector::{FailureDetector, MembershipEvent, MembershipView};
pub use locality::{Cluster, Locality, NetworkConfig};

use std::sync::Arc;

use crate::agas::LocalityId;
use crate::error::{ResilienceError, TaskError, TaskResult};
use crate::future::{when_all_results, Future, Promise};
use crate::resilience::Voter;

/// A distributable task body: runs on whichever locality it is routed
/// to; receives that locality so it can interact with local services
/// (AGAS, local spawns, …).
pub type DistBody<T> = Arc<dyn Fn(&Locality) -> TaskResult<T> + Send + Sync>;

/// Replay across localities: up to `n` total attempts, each retry routed
/// to the next locality in the ring (skipping nothing — a retry landing
/// on another dead locality simply burns an attempt, as on real systems
/// until a failure detector prunes membership).
pub fn async_replay_distributed<T: Clone + Send + 'static>(
    cluster: &Cluster,
    n: usize,
    body: DistBody<T>,
) -> Future<T> {
    let (p, fut) = Promise::new();
    let start = cluster.next_target();
    attempt_on(cluster.clone(), p, body, n.max(1), 1, start);
    fut
}

fn attempt_on<T: Clone + Send + 'static>(
    cluster: Cluster,
    promise: Promise<T>,
    body: DistBody<T>,
    n: usize,
    attempt: usize,
    target: LocalityId,
) {
    let body2 = Arc::clone(&body);
    let inner = cluster.run_on(target, move |loc| body2(loc));
    inner.on_ready(move |r| match r {
        Ok(v) => promise.set_value(v.clone()),
        Err(e) => {
            if attempt < n {
                let next = cluster.next_locality(target);
                attempt_on(cluster.clone(), promise, body, n, attempt + 1, next);
            } else {
                promise.set_error(
                    ResilienceError::Exhausted { attempts: attempt, last: e.clone() }.into(),
                );
            }
        }
    });
}

/// Replicate across localities: `n` replicas, each on a distinct
/// locality (round-robin when `n` exceeds the cluster size). With
/// `vote = None` the lowest-indexed successful replica wins; with a
/// voter, consensus is built over all successful results.
pub fn async_replicate_distributed<T: Clone + Send + 'static>(
    cluster: &Cluster,
    n: usize,
    vote: Option<Voter<T>>,
    body: DistBody<T>,
) -> Future<T> {
    let n = n.max(1);
    let start = cluster.next_target().0;
    let futs: Vec<Future<T>> = (0..n)
        .map(|i| {
            let target = LocalityId((start + i) % cluster.len());
            let body = Arc::clone(&body);
            cluster.run_on(target, move |loc| body(loc))
        })
        .collect();
    when_all_results(futs).then(move |r| {
        let results = match r {
            Ok(results) => results,
            Err(e) => return Err(e.clone()),
        };
        let oks: Vec<T> = results.iter().filter_map(|x| x.as_ref().ok().cloned()).collect();
        if oks.is_empty() {
            let last = results
                .iter()
                .rev()
                .find_map(|x| x.as_ref().err().cloned())
                .unwrap_or(TaskError::App("no replica result".into()));
            return Err(ResilienceError::AllReplicasFailed { replicas: n, last }.into());
        }
        match &vote {
            None => Ok(oks[0].clone()),
            Some(v) => match v(&oks) {
                Some(winner) => Ok(winner),
                None => Err(ResilienceError::NoConsensus { candidates: oks.len() }.into()),
            },
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resilience::vote_majority;

    fn cluster(n: usize) -> Cluster {
        Cluster::new(n, 1, NetworkConfig::default())
    }

    #[test]
    fn run_on_executes_on_target_locality() {
        let cl = cluster(3);
        let f = cl.run_on(LocalityId(2), |loc| Ok::<_, TaskError>(loc.id().0));
        assert_eq!(f.get(), Ok(2));
    }

    #[test]
    fn dead_locality_fails_tasks() {
        let cl = cluster(2);
        cl.kill(LocalityId(1));
        let f = cl.run_on(LocalityId(1), |_| Ok::<_, TaskError>(1));
        assert!(f.get().is_err());
        cl.revive(LocalityId(1));
        let f = cl.run_on(LocalityId(1), |_| Ok::<_, TaskError>(1));
        assert_eq!(f.get(), Ok(1));
    }

    #[test]
    fn distributed_replay_survives_dead_node() {
        let cl = cluster(3);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        // Replay must walk the ring until it lands on locality 2.
        let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
        let f = async_replay_distributed(&cl, 5, body);
        assert_eq!(f.get(), Ok(2));
    }

    #[test]
    fn distributed_replay_exhausts_on_all_dead() {
        let cl = cluster(2);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
        let f = async_replay_distributed(&cl, 4, body);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::Exhausted { attempts: 4, .. }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn distributed_replicate_survives_minority_death() {
        let cl = cluster(3);
        cl.kill(LocalityId(1));
        let body: DistBody<i64> = Arc::new(|_| Ok(42));
        let f = async_replicate_distributed(&cl, 3, Some(Arc::new(vote_majority)), body);
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn distributed_replicate_all_dead_fails() {
        let cl = cluster(2);
        cl.kill(LocalityId(0));
        cl.kill(LocalityId(1));
        let body: DistBody<i64> = Arc::new(|_| Ok(1));
        let f = async_replicate_distributed(&cl, 2, None, body);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { replicas: 2, .. }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicas_fan_out_to_distinct_localities() {
        let cl = cluster(3);
        let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
        // With vote=None the first (lowest-index-launched) replica wins,
        // but all three ran on distinct localities; check by collecting.
        let futs: Vec<Future<usize>> = (0..3)
            .map(|i| {
                let b = Arc::clone(&body);
                cl.run_on(LocalityId(i), move |loc| b(loc))
            })
            .collect();
        let mut ids: Vec<usize> = futs.into_iter().map(|f| f.get().unwrap()).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn agas_is_cluster_wide() {
        let cl = cluster(2);
        let gid = cl.agas().register(LocalityId(0), 7i64);
        let agas = cl.agas().clone();
        let f = cl.run_on(LocalityId(1), move |_| {
            agas.resolve::<i64>(gid)
                .map(|v| *v)
                .ok_or(TaskError::App("gid not found".into()))
        });
        assert_eq!(f.get(), Ok(7));
    }
}
