//! Simulated localities and the active-message layer.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::agas::{Agas, LocalityId};
use crate::api::run_task_body;
use crate::error::{TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::runtime_handle::Runtime;

/// Interconnect model for the simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct NetworkConfig {
    /// One-way message latency in microseconds (0 = loopback).
    pub latency_us: u64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig { latency_us: 0 }
    }
}

/// An active message: a closure executed on the target locality.
type Message = Box<dyn FnOnce(&Locality) + Send + 'static>;

struct LocalityInner {
    id: LocalityId,
    rt: Runtime,
    alive: AtomicBool,
    agas: Agas,
    sent: AtomicUsize,
    executed: AtomicUsize,
    rejected: AtomicUsize,
}

/// One simulated HPX locality: a private scheduler pool plus an
/// active-message mailbox.
#[derive(Clone)]
pub struct Locality {
    inner: Arc<LocalityInner>,
}

impl Locality {
    pub fn id(&self) -> LocalityId {
        self.inner.id
    }

    /// The locality's own runtime (for nested local spawns).
    pub fn runtime(&self) -> &Runtime {
        &self.inner.rt
    }

    /// Cluster-wide AGAS registry.
    pub fn agas(&self) -> &Agas {
        &self.inner.agas
    }

    pub fn is_alive(&self) -> bool {
        self.inner.alive.load(Ordering::SeqCst)
    }

    /// Messages delivered to this locality (metrics).
    pub fn messages_received(&self) -> usize {
        self.inner.sent.load(Ordering::Relaxed)
    }

    /// Task bodies this locality actually ran (placement introspection:
    /// where work physically executed, as opposed to where it was merely
    /// routed).
    pub fn tasks_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Tasks routed here that were rejected because the locality was
    /// dead — each one is a failed attempt some resilience layer had to
    /// absorb.
    pub fn tasks_rejected(&self) -> usize {
        self.inner.rejected.load(Ordering::Relaxed)
    }
}

struct ClusterInner {
    localities: Vec<Locality>,
    mailboxes: Vec<Mutex<mpsc::Sender<Message>>>,
    agas: Agas,
    rr: AtomicUsize,
    net: NetworkConfig,
}

/// An in-process simulation of a multi-locality HPX deployment.
#[derive(Clone)]
pub struct Cluster {
    inner: Arc<ClusterInner>,
}

impl Cluster {
    /// Create `n` localities with `workers` scheduler threads each.
    pub fn new(n: usize, workers: usize, net: NetworkConfig) -> Self {
        let n = n.max(1);
        let agas = Agas::new();
        let mut localities = Vec::with_capacity(n);
        let mut mailboxes = Vec::with_capacity(n);
        for i in 0..n {
            let loc = Locality {
                inner: Arc::new(LocalityInner {
                    id: LocalityId(i),
                    rt: Runtime::builder().workers(workers).build(),
                    alive: AtomicBool::new(true),
                    agas: agas.clone(),
                    sent: AtomicUsize::new(0),
                    executed: AtomicUsize::new(0),
                    rejected: AtomicUsize::new(0),
                }),
            };
            let (tx, rx) = mpsc::channel::<Message>();
            // The active-message pump: one thread per locality delivering
            // mailbox messages onto the locality's scheduler.
            let pump_loc = loc.clone();
            let latency = net.latency_us;
            // Pump threads are detached: they exit when the last
            // cluster handle (and with it the mailbox sender) drops and
            // `recv` disconnects.
            let _pump = std::thread::Builder::new()
                .name(format!("rhpx-amsg-{i}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        if latency > 0 {
                            std::thread::sleep(std::time::Duration::from_micros(latency));
                        }
                        pump_loc.inner.sent.fetch_add(1, Ordering::Relaxed);
                        msg(&pump_loc);
                    }
                })
                .expect("spawn active-message pump");
            localities.push(loc);
            mailboxes.push(Mutex::new(tx));
        }
        Cluster {
            inner: Arc::new(ClusterInner {
                localities,
                mailboxes,
                agas,
                rr: AtomicUsize::new(0),
                net,
            }),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.localities.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn agas(&self) -> &Agas {
        &self.inner.agas
    }

    pub fn network(&self) -> NetworkConfig {
        self.inner.net
    }

    pub fn locality(&self, id: LocalityId) -> &Locality {
        &self.inner.localities[id.0]
    }

    /// Mark a locality failed: tasks routed to it error out.
    pub fn kill(&self, id: LocalityId) {
        self.inner.localities[id.0].inner.alive.store(false, Ordering::SeqCst);
    }

    /// Bring a locality back (post-recovery rejoin).
    pub fn revive(&self, id: LocalityId) {
        self.inner.localities[id.0].inner.alive.store(true, Ordering::SeqCst);
    }

    /// Round-robin target selection for new work.
    pub fn next_target(&self) -> LocalityId {
        LocalityId(self.inner.rr.fetch_add(1, Ordering::Relaxed) % self.len())
    }

    /// Round-robin over the *live* membership only — what a placement
    /// layer that consumes the failure detector's view does. Falls back
    /// to the plain round-robin when every locality is dead (the
    /// submission then fails at the mailbox like any other attempt).
    pub fn next_alive_target(&self) -> LocalityId {
        let alive = self.alive_ids();
        if alive.is_empty() {
            return self.next_target();
        }
        alive[self.inner.rr.fetch_add(1, Ordering::Relaxed) % alive.len()]
    }

    /// The ring successor of `id`.
    pub fn next_locality(&self, id: LocalityId) -> LocalityId {
        LocalityId((id.0 + 1) % self.len())
    }

    /// Ids of the localities currently alive (ascending).
    pub fn alive_ids(&self) -> Vec<LocalityId> {
        self.inner
            .localities
            .iter()
            .filter(|l| l.is_alive())
            .map(|l| l.id())
            .collect()
    }

    /// Ship `f` to locality `target` as an active message; the returned
    /// future resolves with the task's result. Tasks on dead localities
    /// fail with a `locality dead` error (the failure-detector signal the
    /// distributed executors consume).
    pub fn run_on<T, F>(&self, target: LocalityId, f: F) -> Future<T>
    where
        T: Send + 'static,
        F: FnOnce(&Locality) -> TaskResult<T> + Send + 'static,
    {
        let (p, fut) = Promise::new();
        let msg: Message = Box::new(move |loc: &Locality| {
            if !loc.is_alive() {
                loc.inner.rejected.fetch_add(1, Ordering::Relaxed);
                p.set_error(TaskError::App(format!("locality {} dead", loc.id().0)));
                return;
            }
            let loc2 = loc.clone();
            loc.runtime().pool().spawn_job(Box::new(move || {
                if !loc2.is_alive() {
                    loc2.inner.rejected.fetch_add(1, Ordering::Relaxed);
                    p.set_error(TaskError::App(format!("locality {} dead", loc2.id().0)));
                    return;
                }
                loc2.inner.executed.fetch_add(1, Ordering::Relaxed);
                p.set_result(run_task_body(|| f(&loc2)));
            }));
        });
        let tx = self.inner.mailboxes[target.0].lock().unwrap();
        if tx.send(msg).is_err() {
            // Pump gone (cluster shutting down): the promise inside the
            // message was dropped with it → future resolves to broken
            // promise; nothing more to do.
        }
        fut
    }

    /// Broadcast a closure to every live locality.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(&Locality) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        for i in 0..self.len() {
            let f = Arc::clone(&f);
            let _ = self.run_on(LocalityId(i), move |loc| {
                f(loc);
                Ok::<(), TaskError>(())
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_basics() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        assert_eq!(cl.len(), 2);
        assert_eq!(cl.next_locality(LocalityId(1)), LocalityId(0));
        let a = cl.next_target();
        let b = cl.next_target();
        assert_ne!(a, b);
    }

    #[test]
    fn run_on_with_latency() {
        let cl = Cluster::new(1, 1, NetworkConfig { latency_us: 100 });
        let t = crate::metrics::Timer::start();
        let f = cl.run_on(LocalityId(0), |_| Ok::<_, TaskError>(1));
        assert_eq!(f.get(), Ok(1));
        assert!(t.elapsed_micros() >= 100.0);
    }

    #[test]
    fn broadcast_reaches_all() {
        let cl = Cluster::new(3, 1, NetworkConfig::default());
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        cl.broadcast(move |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        // broadcast is fire-and-forget; wait for all localities
        for i in 0..3 {
            cl.locality(LocalityId(i)).runtime().wait_idle();
        }
        // The pump threads may still be delivering; poll briefly.
        for _ in 0..100 {
            if count.load(Ordering::SeqCst) == 3 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn messages_counted() {
        let cl = Cluster::new(1, 1, NetworkConfig::default());
        for _ in 0..5 {
            cl.run_on(LocalityId(0), |_| Ok::<_, TaskError>(0)).get().unwrap();
        }
        assert_eq!(cl.locality(LocalityId(0)).messages_received(), 5);
    }

    #[test]
    fn execution_and_rejection_counters_track_placement() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        for _ in 0..4 {
            cl.run_on(LocalityId(0), |_| Ok::<_, TaskError>(0)).get().unwrap();
        }
        cl.kill(LocalityId(1));
        for _ in 0..3 {
            assert!(cl.run_on(LocalityId(1), |_| Ok::<_, TaskError>(0)).get().is_err());
        }
        assert_eq!(cl.locality(LocalityId(0)).tasks_executed(), 4);
        assert_eq!(cl.locality(LocalityId(0)).tasks_rejected(), 0);
        assert_eq!(cl.locality(LocalityId(1)).tasks_executed(), 0);
        assert_eq!(cl.locality(LocalityId(1)).tasks_rejected(), 3);
        assert_eq!(cl.alive_ids(), vec![LocalityId(0)]);
        cl.revive(LocalityId(1));
        assert_eq!(cl.alive_ids(), vec![LocalityId(0), LocalityId(1)]);
    }
}
