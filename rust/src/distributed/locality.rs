//! Simulated localities and the active-message layer.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::agas::{Agas, LocalityId};
use crate::api::run_task_body;
use crate::error::{TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::runtime_handle::Runtime;
use crate::scheduler::{Job, Lineage, LineageLedger};

/// Interconnect model for the simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct NetworkConfig {
    /// One-way message latency in microseconds (0 = loopback).
    pub latency_us: u64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig { latency_us: 0 }
    }
}

/// An active message: a closure executed on the target locality.
type Message = Box<dyn FnOnce(&Locality) + Send + 'static>;

struct LocalityInner {
    id: LocalityId,
    rt: Runtime,
    alive: AtomicBool,
    agas: Agas,
    sent: AtomicUsize,
    executed: AtomicUsize,
    rejected: AtomicUsize,
    /// Tracked tasks that died *in this locality's queue* — routed here,
    /// never executed, never rejected; drained off on kill.
    lost: AtomicUsize,
    /// Side table of queued-but-unexecuted tracked tasks. Entry presence
    /// is the claim token: a worker must `claim` its epoch before running
    /// the body, and `Cluster::kill` drains whatever is unclaimed.
    ledger: LineageLedger,
}

/// One simulated HPX locality: a private scheduler pool plus an
/// active-message mailbox.
#[derive(Clone)]
pub struct Locality {
    inner: Arc<LocalityInner>,
}

impl Locality {
    pub fn id(&self) -> LocalityId {
        self.inner.id
    }

    /// The locality's own runtime (for nested local spawns).
    pub fn runtime(&self) -> &Runtime {
        &self.inner.rt
    }

    /// Cluster-wide AGAS registry.
    pub fn agas(&self) -> &Agas {
        &self.inner.agas
    }

    pub fn is_alive(&self) -> bool {
        self.inner.alive.load(Ordering::SeqCst)
    }

    /// Messages delivered to this locality (metrics).
    pub fn messages_received(&self) -> usize {
        self.inner.sent.load(Ordering::Relaxed)
    }

    /// Task bodies this locality actually ran (placement introspection:
    /// where work physically executed, as opposed to where it was merely
    /// routed).
    pub fn tasks_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Tasks routed here that were rejected because the locality was
    /// dead — each one is a failed attempt some resilience layer had to
    /// absorb.
    pub fn tasks_rejected(&self) -> usize {
        self.inner.rejected.load(Ordering::Relaxed)
    }

    /// Tracked tasks that sat queued here when the locality was killed —
    /// neither executed nor rejected. Each one was re-materialized onto a
    /// survivor by the kill-time queue drain, so `executed + rejected +
    /// lost` over all localities equals tasks routed (initial submissions
    /// plus re-materializations).
    pub fn tasks_lost(&self) -> usize {
        self.inner.lost.load(Ordering::Relaxed)
    }

    /// Lineage records of tracked tasks still queued (unclaimed) here.
    pub fn pending_lineages(&self) -> Vec<Lineage> {
        self.inner.ledger.lineages()
    }
}

struct ClusterInner {
    localities: Vec<Locality>,
    mailboxes: Vec<Mutex<mpsc::Sender<Message>>>,
    agas: Agas,
    rr: AtomicUsize,
    net: NetworkConfig,
    /// Cluster-wide monotonic epoch minted per tracked submission; the
    /// lineage key that makes claim/drain arbitration exactly-once.
    epoch: AtomicU64,
    /// Drain-to-reschedule latency of each kill-time queue drain.
    drain_latency: Mutex<Vec<Duration>>,
}

/// An in-process simulation of a multi-locality HPX deployment.
#[derive(Clone)]
pub struct Cluster {
    inner: Arc<ClusterInner>,
}

impl Cluster {
    /// Create `n` localities with `workers` scheduler threads each.
    pub fn new(n: usize, workers: usize, net: NetworkConfig) -> Self {
        let n = n.max(1);
        let agas = Agas::new();
        let mut localities = Vec::with_capacity(n);
        let mut mailboxes = Vec::with_capacity(n);
        for i in 0..n {
            let loc = Locality {
                inner: Arc::new(LocalityInner {
                    id: LocalityId(i),
                    rt: Runtime::builder().workers(workers).build(),
                    alive: AtomicBool::new(true),
                    agas: agas.clone(),
                    sent: AtomicUsize::new(0),
                    executed: AtomicUsize::new(0),
                    rejected: AtomicUsize::new(0),
                    lost: AtomicUsize::new(0),
                    ledger: LineageLedger::new(),
                }),
            };
            let (tx, rx) = mpsc::channel::<Message>();
            // The active-message pump: one thread per locality delivering
            // mailbox messages onto the locality's scheduler.
            let pump_loc = loc.clone();
            let latency = net.latency_us;
            // Pump threads are detached: they exit when the last
            // cluster handle (and with it the mailbox sender) drops and
            // `recv` disconnects.
            let _pump = std::thread::Builder::new()
                .name(format!("rhpx-amsg-{i}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        if latency > 0 {
                            std::thread::sleep(std::time::Duration::from_micros(latency));
                        }
                        pump_loc.inner.sent.fetch_add(1, Ordering::Relaxed);
                        msg(&pump_loc);
                    }
                })
                .expect("spawn active-message pump");
            localities.push(loc);
            mailboxes.push(Mutex::new(tx));
        }
        Cluster {
            inner: Arc::new(ClusterInner {
                localities,
                mailboxes,
                agas,
                rr: AtomicUsize::new(0),
                net,
                epoch: AtomicU64::new(0),
                drain_latency: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.localities.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn agas(&self) -> &Agas {
        &self.inner.agas
    }

    pub fn network(&self) -> NetworkConfig {
        self.inner.net
    }

    pub fn locality(&self, id: LocalityId) -> &Locality {
        &self.inner.localities[id.0]
    }

    /// Mark a locality failed: tasks routed to it error out, and tracked
    /// tasks still queued on it are re-materialized onto survivors from
    /// their lineage records (resilient work stealing: no global barrier,
    /// survivors inherit the corpse's pending work).
    pub fn kill(&self, id: LocalityId) {
        self.inner.localities[id.0].inner.alive.store(false, Ordering::SeqCst);
        self.drain_pending(id);
    }

    /// Drain the corpse's lineage ledger and relaunch every unclaimed
    /// task on a live locality. Claim and drain are mutually exclusive
    /// per epoch (the ledger mutex arbitrates), so a task observed here
    /// can no longer start on the corpse — and a task already claimed by
    /// a corpse worker runs to completion there instead of appearing
    /// twice.
    fn drain_pending(&self, id: LocalityId) {
        let started = Instant::now();
        let drained = self.inner.localities[id.0].inner.ledger.drain();
        if drained.is_empty() {
            return;
        }
        self.inner.localities[id.0]
            .inner
            .lost
            .fetch_add(drained.len(), Ordering::Relaxed);
        for (_lineage, relaunch) in drained {
            relaunch();
        }
        self.inner.drain_latency.lock().unwrap().push(started.elapsed());
    }

    /// Drain-to-reschedule latency of each kill-time queue drain so far,
    /// in seconds (one entry per kill that found pending work).
    pub fn drain_latency_secs(&self) -> Vec<f64> {
        self.inner
            .drain_latency
            .lock()
            .unwrap()
            .iter()
            .map(|d| d.as_secs_f64())
            .collect()
    }

    /// Bring a locality back (post-recovery rejoin).
    pub fn revive(&self, id: LocalityId) {
        self.inner.localities[id.0].inner.alive.store(true, Ordering::SeqCst);
    }

    /// Round-robin target selection for new work.
    pub fn next_target(&self) -> LocalityId {
        LocalityId(self.inner.rr.fetch_add(1, Ordering::Relaxed) % self.len())
    }

    /// Round-robin over the *live* membership only — what a placement
    /// layer that consumes the failure detector's view does. Falls back
    /// to the plain round-robin when every locality is dead (the
    /// submission then fails at the mailbox like any other attempt).
    pub fn next_alive_target(&self) -> LocalityId {
        let alive = self.alive_ids();
        if alive.is_empty() {
            return self.next_target();
        }
        alive[self.inner.rr.fetch_add(1, Ordering::Relaxed) % alive.len()]
    }

    /// The ring successor of `id`.
    pub fn next_locality(&self, id: LocalityId) -> LocalityId {
        LocalityId((id.0 + 1) % self.len())
    }

    /// Ids of the localities currently alive (ascending).
    pub fn alive_ids(&self) -> Vec<LocalityId> {
        self.inner
            .localities
            .iter()
            .filter(|l| l.is_alive())
            .map(|l| l.id())
            .collect()
    }

    /// Ship `f` to locality `target` as an active message; the returned
    /// future resolves with the task's result. Tasks on dead localities
    /// fail with a `locality dead` error (the failure-detector signal the
    /// distributed executors consume).
    pub fn run_on<T, F>(&self, target: LocalityId, f: F) -> Future<T>
    where
        T: Send + 'static,
        F: FnOnce(&Locality) -> TaskResult<T> + Send + 'static,
    {
        let (p, fut) = Promise::new();
        let msg: Message = Box::new(move |loc: &Locality| {
            if !loc.is_alive() {
                loc.inner.rejected.fetch_add(1, Ordering::Relaxed);
                p.set_error(TaskError::App(format!("locality {} dead", loc.id().0)));
                return;
            }
            let loc2 = loc.clone();
            loc.runtime().pool().spawn_job(Box::new(move || {
                if !loc2.is_alive() {
                    loc2.inner.rejected.fetch_add(1, Ordering::Relaxed);
                    p.set_error(TaskError::App(format!("locality {} dead", loc2.id().0)));
                    return;
                }
                loc2.inner.executed.fetch_add(1, Ordering::Relaxed);
                p.set_result(run_task_body(|| f(&loc2)));
            }));
        });
        let tx = self.inner.mailboxes[target.0].lock().unwrap();
        if tx.send(msg).is_err() {
            // Pump gone (cluster shutting down): the promise inside the
            // message was dropped with it → future resolves to broken
            // promise; nothing more to do.
        }
        fut
    }

    /// Ship a *tracked* task to locality `target`: like [`run_on`], but
    /// the submission is registered in the target's lineage ledger
    /// (origin locality, spawn `parent` epoch, fresh monotonic epoch)
    /// until a worker claims it. If the target is killed while the task
    /// still sits queued, [`kill`] drains the ledger and re-materializes
    /// the task onto a survivor — the future then resolves with the
    /// survivor's result, so a backlogged kill loses no work.
    ///
    /// Liveness is checked at submit time on the caller's thread (the
    /// same thread `FaultSchedule` advances kills on, which keeps the
    /// executed/rejected/lost accounting deterministic): a dead target
    /// rejects immediately and the future fails, exactly like `run_on`.
    ///
    /// [`run_on`]: Cluster::run_on
    /// [`kill`]: Cluster::kill
    pub fn run_on_resilient<T>(
        &self,
        target: LocalityId,
        parent: Option<u64>,
        body: Arc<dyn Fn(&Locality) -> TaskResult<T> + Send + Sync>,
    ) -> Future<T>
    where
        T: Send + 'static,
    {
        let (p, fut) = Promise::new();
        self.submit_tracked(target, parent, body, Arc::new(Mutex::new(Some(p))), false);
        fut
    }

    /// Like [`run_on_resilient`], but placement is advisory: if `target`
    /// turns out to be dead at the submit-time check (a kill landed
    /// between choosing it and submitting — the race a concurrent
    /// `FaultSchedule` opens against dataflow continuations), the task is
    /// re-routed to [`next_alive_target`] instead of rejected. This is
    /// the placement mode behind live-only routing (`--resilience
    /// drain`), which has no decorator retry to absorb a rejection; the
    /// re-pick is not counted as a routing, so the
    /// executed/rejected/lost accounting is identical to a first-try
    /// landing.
    ///
    /// [`run_on_resilient`]: Cluster::run_on_resilient
    /// [`next_alive_target`]: Cluster::next_alive_target
    pub fn run_on_resilient_routed<T>(
        &self,
        target: LocalityId,
        parent: Option<u64>,
        body: Arc<dyn Fn(&Locality) -> TaskResult<T> + Send + Sync>,
    ) -> Future<T>
    where
        T: Send + 'static,
    {
        let (p, fut) = Promise::new();
        self.submit_tracked(target, parent, body, Arc::new(Mutex::new(Some(p))), true);
        fut
    }

    /// One tracked routing attempt. Exactly one of three counters is
    /// bumped per call: `rejected` (dead at submit), `executed` (a worker
    /// claimed and ran it), or `lost` (killed in queue — in which case
    /// the recorded relaunch closure re-enters this function on a
    /// survivor, which counts as a fresh routing).
    ///
    /// With `reroute`, a dead-at-submit target is not a routing at all:
    /// the attempt silently re-picks a live target and tries again, so no
    /// counter moves until the task actually lands somewhere.
    fn submit_tracked<T>(
        &self,
        target: LocalityId,
        parent: Option<u64>,
        body: Arc<dyn Fn(&Locality) -> TaskResult<T> + Send + Sync>,
        slot: Arc<Mutex<Option<Promise<T>>>>,
        reroute: bool,
    ) where
        T: Send + 'static,
    {
        let mut target = target;
        let loc = loop {
            let loc = &self.inner.localities[target.0];
            if loc.is_alive() {
                break loc;
            }
            if reroute && !self.alive_ids().is_empty() {
                target = self.next_alive_target();
                continue;
            }
            loc.inner.rejected.fetch_add(1, Ordering::Relaxed);
            if let Some(p) = slot.lock().unwrap().take() {
                p.set_error(TaskError::App(format!("locality {} dead", target.0)));
            }
            return;
        };
        let epoch = self.inner.epoch.fetch_add(1, Ordering::Relaxed);
        // The relaunch closure stored with the lineage record: on drain it
        // re-submits the same body (and the same promise slot) to a live
        // locality. It holds the cluster weakly so ledgers don't keep the
        // cluster alive past the last user handle.
        let weak = Arc::downgrade(&self.inner);
        let rl_body = Arc::clone(&body);
        let rl_slot = Arc::clone(&slot);
        let relaunch: Job = Box::new(move || {
            if let Some(inner) = weak.upgrade() {
                let cluster = Cluster { inner };
                // Re-materialization always reroutes: the lost task was
                // already counted, and its relaunch must land on a
                // survivor even if another kill races the re-pick.
                let next = cluster.next_alive_target();
                cluster.submit_tracked(next, Some(epoch), rl_body, rl_slot, true);
            }
        });
        loc.inner.ledger.record(Lineage { origin: target.0, parent, epoch }, relaunch);
        let msg: Message = Box::new(move |loc: &Locality| {
            let loc2 = loc.clone();
            loc.runtime().pool().spawn_job(Box::new(move || {
                // Claiming the epoch is the exactly-once gate: if the
                // kill-time drain got there first the entry is gone, the
                // corpse's worker drops the task silently, and the
                // re-materialized copy owns the promise. If the claim
                // succeeds the task runs to completion even mid-kill —
                // claimed in-flight work is never duplicated.
                if !loc2.inner.ledger.claim(epoch) {
                    return;
                }
                loc2.inner.executed.fetch_add(1, Ordering::Relaxed);
                let result = run_task_body(|| body(&loc2));
                if let Some(p) = slot.lock().unwrap().take() {
                    p.set_result(result);
                }
            }));
        });
        let tx = self.inner.mailboxes[target.0].lock().unwrap();
        if tx.send(msg).is_err() {
            // Pump gone (cluster shutting down). The ledger entry stays;
            // it drops with the cluster and the promise reports broken.
        }
    }

    /// Broadcast a closure to every live locality.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(&Locality) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        for i in 0..self.len() {
            let f = Arc::clone(&f);
            let _ = self.run_on(LocalityId(i), move |loc| {
                f(loc);
                Ok::<(), TaskError>(())
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_basics() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        assert_eq!(cl.len(), 2);
        assert_eq!(cl.next_locality(LocalityId(1)), LocalityId(0));
        let a = cl.next_target();
        let b = cl.next_target();
        assert_ne!(a, b);
    }

    #[test]
    fn run_on_with_latency() {
        let cl = Cluster::new(1, 1, NetworkConfig { latency_us: 100 });
        let t = crate::metrics::Timer::start();
        let f = cl.run_on(LocalityId(0), |_| Ok::<_, TaskError>(1));
        assert_eq!(f.get(), Ok(1));
        assert!(t.elapsed_micros() >= 100.0);
    }

    #[test]
    fn broadcast_reaches_all() {
        let cl = Cluster::new(3, 1, NetworkConfig::default());
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        cl.broadcast(move |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        // broadcast is fire-and-forget; wait for all localities
        for i in 0..3 {
            cl.locality(LocalityId(i)).runtime().wait_idle();
        }
        // The pump threads may still be delivering; poll briefly.
        for _ in 0..100 {
            if count.load(Ordering::SeqCst) == 3 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn messages_counted() {
        let cl = Cluster::new(1, 1, NetworkConfig::default());
        for _ in 0..5 {
            cl.run_on(LocalityId(0), |_| Ok::<_, TaskError>(0)).get().unwrap();
        }
        assert_eq!(cl.locality(LocalityId(0)).messages_received(), 5);
    }

    #[test]
    fn tracked_submission_executes_once_and_counts() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        let runs = Arc::new(AtomicUsize::new(0));
        let r = Arc::clone(&runs);
        let f = cl.run_on_resilient(
            LocalityId(0),
            None,
            Arc::new(move |_loc: &Locality| {
                r.fetch_add(1, Ordering::SeqCst);
                Ok::<_, TaskError>(7)
            }),
        );
        assert_eq!(f.get(), Ok(7));
        assert_eq!(runs.load(Ordering::SeqCst), 1);
        assert_eq!(cl.locality(LocalityId(0)).tasks_executed(), 1);
        assert_eq!(cl.locality(LocalityId(0)).tasks_lost(), 0);
        assert!(cl.locality(LocalityId(0)).pending_lineages().is_empty());
        assert!(cl.drain_latency_secs().is_empty());
    }

    #[test]
    fn tracked_submission_to_dead_locality_rejects_at_submit_time() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        cl.kill(LocalityId(1));
        let f = cl.run_on_resilient(
            LocalityId(1),
            None,
            Arc::new(|_loc: &Locality| Ok::<_, TaskError>(0)),
        );
        assert!(f.get().is_err());
        assert_eq!(cl.locality(LocalityId(1)).tasks_rejected(), 1);
        assert_eq!(cl.locality(LocalityId(1)).tasks_lost(), 0);
    }

    #[test]
    fn kill_drains_queued_tracked_tasks_onto_survivors() {
        // One worker per locality so a blocker task lets tracked work
        // pile up unclaimed behind it in locality 1's queue.
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let (entered_tx, entered_rx) = mpsc::channel::<()>();
        let blocker = cl.run_on(LocalityId(1), move |_| {
            entered_tx.send(()).unwrap();
            gate_rx.recv().unwrap();
            Ok::<_, TaskError>(0)
        });
        entered_rx.recv().unwrap(); // the single worker is now pinned
        const K: usize = 4;
        let mut futs = Vec::new();
        for i in 0..K {
            futs.push(cl.run_on_resilient(
                LocalityId(1),
                None,
                Arc::new(move |loc: &Locality| Ok::<_, TaskError>((loc.id().0, i))),
            ));
        }
        assert_eq!(cl.locality(LocalityId(1)).pending_lineages().len(), K);
        cl.kill(LocalityId(1));
        // Every queued task was re-materialized; the futures resolve with
        // results computed on the survivor, not errors.
        for (i, f) in futs.into_iter().enumerate() {
            assert_eq!(f.get(), Ok((0, i)));
        }
        gate_tx.send(()).unwrap();
        assert_eq!(blocker.get(), Ok(0));
        assert_eq!(cl.locality(LocalityId(1)).tasks_lost(), K);
        // Locality 1 executed only the untracked blocker; all K tracked
        // bodies ran on the survivor.
        assert_eq!(cl.locality(LocalityId(1)).tasks_executed(), 1);
        assert_eq!(cl.locality(LocalityId(0)).tasks_executed(), K);
        assert_eq!(cl.drain_latency_secs().len(), 1);
        // Invariant: executed + rejected + lost over the cluster equals
        // tasks routed — K initial tracked routings, K re-materialized
        // routings, plus the blocker.
        let routed: usize = (0..2)
            .map(|i| {
                let l = cl.locality(LocalityId(i));
                l.tasks_executed() + l.tasks_rejected() + l.tasks_lost()
            })
            .sum();
        assert_eq!(routed, K + K + 1);
    }

    #[test]
    fn rematerialized_lineage_records_its_parent_epoch() {
        // Pin the single worker of BOTH localities so the re-materialized
        // task stays queued on the survivor long enough to inspect its
        // lineage record.
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        let mut gates = Vec::new();
        let mut blockers = Vec::new();
        for i in 0..2 {
            let (gate_tx, gate_rx) = mpsc::channel::<()>();
            let (entered_tx, entered_rx) = mpsc::channel::<()>();
            blockers.push(cl.run_on(LocalityId(i), move |_| {
                entered_tx.send(()).unwrap();
                gate_rx.recv().unwrap();
                Ok::<_, TaskError>(0)
            }));
            entered_rx.recv().unwrap();
            gates.push(gate_tx);
        }
        assert!(cl.locality(LocalityId(1)).pending_lineages().is_empty());
        let f = cl.run_on_resilient(
            LocalityId(1),
            None,
            Arc::new(|_loc: &Locality| Ok::<_, TaskError>(1)),
        );
        let orig = cl.locality(LocalityId(1)).pending_lineages();
        assert_eq!(orig.len(), 1);
        assert_eq!(orig[0].origin, 1);
        assert_eq!(orig[0].parent, None);
        cl.kill(LocalityId(1));
        // The relaunch landed on the survivor with the corpse's epoch as
        // its spawn parent.
        let re = cl.locality(LocalityId(0)).pending_lineages();
        assert_eq!(re.len(), 1);
        assert_eq!(re[0].origin, 0);
        assert_eq!(re[0].parent, Some(orig[0].epoch));
        assert!(re[0].epoch > orig[0].epoch);
        for g in gates {
            let _ = g.send(());
        }
        assert_eq!(f.get(), Ok(1));
        for b in blockers {
            let _ = b.get();
        }
    }

    #[test]
    fn execution_and_rejection_counters_track_placement() {
        let cl = Cluster::new(2, 1, NetworkConfig::default());
        for _ in 0..4 {
            cl.run_on(LocalityId(0), |_| Ok::<_, TaskError>(0)).get().unwrap();
        }
        cl.kill(LocalityId(1));
        for _ in 0..3 {
            assert!(cl.run_on(LocalityId(1), |_| Ok::<_, TaskError>(0)).get().is_err());
        }
        assert_eq!(cl.locality(LocalityId(0)).tasks_executed(), 4);
        assert_eq!(cl.locality(LocalityId(0)).tasks_rejected(), 0);
        assert_eq!(cl.locality(LocalityId(1)).tasks_executed(), 0);
        assert_eq!(cl.locality(LocalityId(1)).tasks_rejected(), 3);
        assert_eq!(cl.alive_ids(), vec![LocalityId(0)]);
        cl.revive(LocalityId(1));
        assert_eq!(cl.alive_ids(), vec![LocalityId(0), LocalityId(1)]);
    }
}
