//! Process-backed localities: real worker processes behind the
//! [`TaskLauncher`] seam, heartbeat failure detection, literal `kill -9`
//! recovery.
//!
//! The simulated [`Cluster`](super::Cluster) routes "remote" tasks onto
//! in-process scheduler pools, so locality death is bookkeeping. This
//! module promotes localities to OS processes: `rhpx worker` runs one
//! locality as a child process serving the [`crate::serve::protocol`]
//! framing over TCP ([`Frame::Launch`]/[`Frame::TaskResult`] carry task
//! descriptors and results as [`SnapshotData`] bytes), and the
//! parent-side [`ProcCluster`] spawns the children, routes launches, and
//! collects results into local [`Future`]s through [`ProcExec`] — so the
//! workload-zoo engine and every `--resilience` decorator run unchanged
//! on either substrate (`--cluster proc:N`).
//!
//! The failure story is honest on this route:
//!
//! * **Detection** is periodic heartbeating (the ORNL
//!   resilience-design-patterns monitoring pattern, arXiv 1611.02717):
//!   workers emit [`Frame::Heartbeat`] every period, and the pure
//!   [`HeartbeatMonitor`] state machine — generalizing
//!   [`FailureDetector`](super::FailureDetector) from "probe task
//!   rejected" to "K consecutive periods missed" — declares a locality
//!   dead. Nothing tells the monitor about a kill; it has to notice.
//! * **Fault injection** is a real `SIGKILL` of the child's PID
//!   ([`ProcCluster::kill`], driven by the same `kill=STEP@LOC` schedule
//!   grammar as the simulated route), plus a worker self-crash flag
//!   (`crash=N@LOC` → `std::process::abort` on the N-th launch) for
//!   deterministic CI.
//! * **Recovery** re-materializes the corpse's in-flight launches on
//!   survivors (the *Resilient Work Stealing* lineage pattern, arXiv
//!   1706.03539): at the death verdict every pending call homed on the
//!   corpse is drained, counted `lost`, and — when the run is resilient
//!   — re-sent to a live worker from its retained descriptor. Without
//!   resilience the loss surfaces as a poisoned slot (survival < 1),
//!   never a hang.
//!
//! Task bodies ship by *name*, not by closure: [`Frame::Launch`] carries
//! a [`TaskDesc`] (workload name, scale, layer, slot index, input chunk
//! bytes) and the worker rebuilds the body from its own
//! [`crate::workloads`] registry — sound because workload bodies are
//! pure and deterministic by trait contract, which is also what makes
//! the recovered run bit-identical to a pool run.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::agas::LocalityId;
use crate::checkpoint::store::{MemorySnapshotStore, SnapshotData, SnapshotStore};
use crate::error::{TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::resilience::executor::{TaskFn, TaskLauncher};
use crate::serve::protocol::{Frame, FrameError, TaskDesc};
use crate::stencil::{Chunk, LocalityReport};
use crate::workloads::{self, TaskSpec, Workload};

use super::{FaultSchedule, KillEvent};

/// Default worker heartbeat period.
pub const DEFAULT_HEARTBEAT_MS: u64 = 20;

/// Default missed-period threshold: a locality is declared dead after
/// this many heartbeat periods elapse with no frame from it.
pub const DEFAULT_K_MISSED: u64 = 5;

// ---------------------------------------------------------------------
// HeartbeatMonitor — the pure detection state machine
// ---------------------------------------------------------------------

/// Missed-heartbeat failure detection as a pure, clockless state
/// machine: callers feed it observed beats ([`HeartbeatMonitor::beat`])
/// and time ([`HeartbeatMonitor::poll`]); it owns only the verdict rule.
/// [`ProcCluster`] drives it from a real-clock monitor thread; the
/// deterministic-schedule tests drive it from a virtual clock — same
/// transitions either way.
///
/// The rule: locality `i` is declared dead at the first `poll(now)` with
/// `now - last_beat(i) >= k_missed * period_ms` — exactly K missed
/// periods, inclusive. A verdict is final: process death is not
/// recoverable in place (a late beat racing the verdict is ignored; the
/// replacement story is a fresh worker, not a resurrection).
#[derive(Debug, Clone)]
pub struct HeartbeatMonitor {
    period_ms: u64,
    k_missed: u64,
    /// Timestamp (ms) of the last frame seen from each locality.
    last_beat: Vec<u64>,
    dead: Vec<bool>,
}

impl HeartbeatMonitor {
    /// Monitor `localities` workers, all treated as having beaten at
    /// `now_ms` (spawn time counts as the zeroth beat: a worker that
    /// never says hello is detected like any other silence).
    pub fn new(localities: usize, period_ms: u64, k_missed: u64, now_ms: u64) -> Self {
        HeartbeatMonitor {
            period_ms: period_ms.max(1),
            k_missed: k_missed.max(1),
            last_beat: vec![now_ms; localities],
            dead: vec![false; localities],
        }
    }

    /// Record a frame from `loc` at `now_ms`. Returns false (and changes
    /// nothing) when the verdict already fell: death is final, so a beat
    /// racing the verdict loses in whichever order it arrives after it.
    pub fn beat(&mut self, loc: LocalityId, now_ms: u64) -> bool {
        match (self.dead.get(loc.0), self.last_beat.get_mut(loc.0)) {
            (Some(false), Some(last)) => {
                *last = (*last).max(now_ms);
                true
            }
            _ => false,
        }
    }

    /// Advance the verdict clock: returns the localities *newly*
    /// declared dead at `now_ms` (each is reported exactly once).
    pub fn poll(&mut self, now_ms: u64) -> Vec<LocalityId> {
        let deadline = self.period_ms * self.k_missed;
        let mut newly = Vec::new();
        for i in 0..self.last_beat.len() {
            if !self.dead[i] && now_ms.saturating_sub(self.last_beat[i]) >= deadline {
                self.dead[i] = true;
                newly.push(LocalityId(i));
            }
        }
        newly
    }

    pub fn is_dead(&self, loc: LocalityId) -> bool {
        self.dead.get(loc.0).copied().unwrap_or(false)
    }

    /// Localities not (yet) declared dead.
    pub fn alive_ids(&self) -> Vec<LocalityId> {
        (0..self.dead.len()).filter(|&i| !self.dead[i]).map(LocalityId).collect()
    }

    /// The silence (ms) that triggers a verdict.
    pub fn deadline_ms(&self) -> u64 {
        self.period_ms * self.k_missed
    }

    /// Whole heartbeat periods elapsed since `loc` was last heard from —
    /// 0 for a prompt worker, rising toward `k_missed` as the verdict
    /// nears. 0 for dead or unknown localities (their silence is priced
    /// by the verdict, not the miss counter).
    pub fn missed_periods(&self, loc: LocalityId, now_ms: u64) -> u64 {
        match (self.dead.get(loc.0), self.last_beat.get(loc.0)) {
            (Some(false), Some(&last)) => now_ms.saturating_sub(last) / self.period_ms,
            _ => 0,
        }
    }
}

// ---------------------------------------------------------------------
// ProcSpec — what `--cluster proc:N[:kill=STEP@LOC][:crash=N@LOC]` parses to
// ---------------------------------------------------------------------

/// Declarative description of a process-backed cluster: worker count,
/// the `SIGKILL` schedule (same `kill=STEP@LOC` grammar and driver-step
/// clock as the simulated [`FaultSchedule`]), an optional worker
/// self-crash event, and the heartbeat tuning.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProcSpec {
    pub localities: usize,
    /// `kill=STEP@LOC` events, fired by the driver's task counter as a
    /// real `SIGKILL` of the worker's PID.
    pub schedule: FaultSchedule,
    /// `crash=N@LOC`: worker `LOC` calls `std::process::abort()` on its
    /// N-th (1-based) received launch — process death without the parent
    /// lifting a finger, for deterministic CI.
    pub crash: Option<KillEvent>,
    pub heartbeat_ms: u64,
    pub k_missed: u64,
    /// Workload geometry authority shared with workers: both sides build
    /// the workload at `scale_milli / 1000`, so layer/slot indices in
    /// [`TaskDesc`] resolve to the same DAG on both ends.
    pub scale_milli: u32,
    /// Flight-recorder spool directory (`--trace`): workers fsync their
    /// trace chunks to `<dir>/locN.spool` *and* stream them to the
    /// parent, so a SIGKILLed worker's final events survive in the file.
    pub trace_spool: Option<PathBuf>,
}

impl ProcSpec {
    /// A fault-free spec with default heartbeat tuning and scale 1.0.
    pub fn new(localities: usize) -> Self {
        ProcSpec {
            localities: localities.max(1),
            schedule: FaultSchedule::default(),
            crash: None,
            heartbeat_ms: DEFAULT_HEARTBEAT_MS,
            k_missed: DEFAULT_K_MISSED,
            scale_milli: 1000,
            trace_spool: None,
        }
    }

    /// Parse `N[:kill=STEP@LOC,...][:crash=N@LOC]` (the `proc:` prefix is
    /// stripped by the CLI; events may share one `:`-segment, comma
    /// separated, like the simulated grammar).
    ///
    /// ```
    /// use rhpx::distributed::ProcSpec;
    ///
    /// let s = ProcSpec::parse("3:kill=6@1").unwrap();
    /// assert_eq!(s.localities, 3);
    /// assert_eq!(s.schedule.events()[0].step, 6);
    /// let c = ProcSpec::parse("3:crash=2@0").unwrap();
    /// assert_eq!(c.crash.unwrap().step, 2);
    /// assert!(ProcSpec::parse("0").is_err());
    /// assert!(ProcSpec::parse("3:crash=2@9").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<ProcSpec, String> {
        let (count, rest) = match s.split_once(':') {
            Some((c, r)) => (c, Some(r)),
            None => (s, None),
        };
        let localities: usize = count
            .parse()
            .ok()
            .filter(|n| *n >= 1)
            .ok_or_else(|| format!("bad worker count {count:?} (expected >= 1)"))?;
        let mut kills: Vec<&str> = Vec::new();
        let mut crash: Option<KillEvent> = None;
        if let Some(rest) = rest {
            for part in rest.split(',').map(str::trim) {
                if let Some(ev) = part.strip_prefix("crash=") {
                    let (n, loc) = ev.split_once('@').ok_or_else(|| {
                        format!("bad crash event {part:?} (expected crash=N@LOC)")
                    })?;
                    let step: usize = n
                        .parse()
                        .ok()
                        .filter(|n| *n >= 1)
                        .ok_or_else(|| format!("crash launch count {n:?} must be >= 1"))?;
                    let loc: usize = loc
                        .parse()
                        .map_err(|_| format!("crash locality {loc:?} is not a number"))?;
                    if loc >= localities {
                        return Err(format!(
                            "crash locality {loc} out of range (workers={localities})"
                        ));
                    }
                    if crash.is_some() {
                        return Err("at most one crash= event".into());
                    }
                    crash = Some(KillEvent { step, loc: LocalityId(loc) });
                } else {
                    kills.push(part);
                }
            }
        }
        let schedule = if kills.is_empty() {
            FaultSchedule::default()
        } else {
            FaultSchedule::parse(&kills.join(","), localities)?
        };
        Ok(ProcSpec { schedule, crash, ..ProcSpec::new(localities) })
    }
}

// ---------------------------------------------------------------------
// Worker-binary resolution
// ---------------------------------------------------------------------

/// Locate the `rhpx` binary whose `worker` subcommand the children run.
/// Resolution: the `RHPX_WORKER_BIN` env var (tests set it from
/// `CARGO_BIN_EXE_rhpx`), then the current executable when it *is* the
/// CLI, then an `rhpx` sibling of the current executable (bench binaries
/// live next to it in `target/<profile>/`).
pub fn worker_binary() -> Result<PathBuf, String> {
    if let Ok(p) = std::env::var("RHPX_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let is_cli = exe
        .file_stem()
        .and_then(|n| n.to_str())
        .map_or(false, |n| n == "rhpx");
    if is_cli {
        return Ok(exe);
    }
    for dir in exe.parent().into_iter().flat_map(|d| [Some(d), d.parent()]).flatten() {
        let candidate = dir.join(if cfg!(windows) { "rhpx.exe" } else { "rhpx" });
        if candidate.is_file() {
            return Ok(candidate);
        }
    }
    Err("cannot locate the rhpx worker binary; set RHPX_WORKER_BIN".into())
}

// ---------------------------------------------------------------------
// Shared framing helpers
// ---------------------------------------------------------------------

/// Encode and write one frame under the writer lock; false on any I/O
/// error (the peer is gone — callers treat it as a dispatch rejection).
fn send_locked(writer: &Mutex<TcpStream>, frame: &Frame) -> bool {
    writer.lock().unwrap().write_all(&frame.encode()).is_ok()
}

// ---------------------------------------------------------------------
// The worker side: `rhpx worker`
// ---------------------------------------------------------------------

/// `rhpx worker` flags.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Parent address to connect back to (`HOST:PORT`).
    pub connect: String,
    /// This worker's locality id.
    pub id: u32,
    pub heartbeat_ms: u64,
    /// Abort the process on the N-th (1-based) received launch.
    pub crash_after: Option<u64>,
    /// Enable the flight recorder and fsync its chunks to
    /// `<dir>/loc<id>.spool` (also streamed to the parent as
    /// [`Frame::Trace`]).
    pub trace_spool: Option<PathBuf>,
}

/// Run one locality: connect to the parent, say hello (a
/// [`Frame::Heartbeat`] with `seq` 0), stream heartbeats from a side
/// thread, and serve [`Frame::Launch`]es until the parent hangs up.
/// Blocks for the process lifetime.
pub fn run_worker(cfg: &WorkerConfig) -> Result<(), String> {
    let stream = TcpStream::connect(&cfg.connect)
        .map_err(|e| format!("worker {}: connect {}: {e}", cfg.id, cfg.connect))?;
    stream.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(
        stream.try_clone().map_err(|e| format!("worker {}: clone stream: {e}", cfg.id))?,
    ));
    if !send_locked(&writer, &Frame::Heartbeat { locality: cfg.id, seq: 0 }) {
        return Err(format!("worker {}: parent rejected hello", cfg.id));
    }

    // Flight recorder: fsync chunks locally (they survive our own
    // SIGKILL) and stream the identical bytes to the parent.
    let mut spool = match &cfg.trace_spool {
        Some(dir) => {
            crate::trace::enable();
            Some(
                crate::trace::spool::SpoolWriter::create(dir, cfg.id)
                    .map_err(|e| format!("worker {}: create trace spool: {e}", cfg.id))?,
            )
        }
        None => None,
    };

    // Heartbeats ride a dedicated thread so a long task body cannot
    // silence a healthy worker (the slow-but-alive case the monitor must
    // not false-positive on). The thread dies with the process. Every
    // 8th beat piggybacks a perfcounter snapshot for the parent to fold.
    {
        let writer = Arc::clone(&writer);
        let (id, period) = (cfg.id, cfg.heartbeat_ms.max(1));
        std::thread::Builder::new()
            .name("rhpx-worker-beat".into())
            .spawn(move || {
                for seq in 1u64.. {
                    std::thread::sleep(Duration::from_millis(period));
                    if !send_locked(&writer, &Frame::Heartbeat { locality: id, seq }) {
                        return;
                    }
                    if seq % 8 == 0 {
                        let counters: Vec<(String, u64)> =
                            crate::perfcounters::global().snapshot().into_iter().collect();
                        if !counters.is_empty()
                            && !send_locked(&writer, &Frame::Counters { locality: id, counters })
                        {
                            return;
                        }
                    }
                }
            })
            .map_err(|e| format!("worker {}: spawn beat thread: {e}", cfg.id))?;
    }

    // Workloads are rebuilt once per (name, scale) and reused across
    // launches; bodies are pure, so cached geometry is always valid.
    let mut cache: HashMap<(String, u32), Box<dyn Workload>> = HashMap::new();
    // Mirrored checkpoint snapshots (Frame::Snapshot): retained so the
    // parent-side store can re-home them off a future corpse.
    let mut snapshots: HashMap<String, Vec<u8>> = HashMap::new();
    let mut launches = 0u64;

    let mut reader = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16384];
    loop {
        loop {
            match Frame::decode(&buf) {
                Ok((frame, consumed)) => {
                    buf.drain(..consumed);
                    match frame {
                        Frame::Launch(desc) => {
                            launches += 1;
                            crate::trace::emit(
                                crate::trace::EventKind::ExecBegin,
                                desc.task_id,
                                cfg.id as u64,
                            );
                            crate::perfcounters::global()
                                .counter("/worker/count/launches")
                                .increment(1);
                            if cfg.crash_after == Some(launches) {
                                // The deterministic-CI stand-in for
                                // SIGKILL: die mid-task, reply never sent.
                                // Flush the spool first so the post-mortem
                                // shows the fatal launch as unfinished.
                                if let Some(s) = spool.as_mut() {
                                    let d = crate::trace::drain_all();
                                    s.append(&d.events, d.dropped).ok();
                                }
                                std::process::abort();
                            }
                            let reply = execute_launch(&mut cache, &desc);
                            crate::trace::emit(
                                crate::trace::EventKind::ExecEnd,
                                desc.task_id,
                                cfg.id as u64,
                            );
                            if !send_locked(&writer, &reply) {
                                return Ok(()); // parent gone
                            }
                            if let Some(s) = spool.as_mut() {
                                let d = crate::trace::drain_all();
                                match s.append(&d.events, d.dropped) {
                                    // The spool is authoritative; streaming is
                                    // best-effort (a dead parent reads the
                                    // spool instead).
                                    Ok(chunks) => {
                                        for chunk in chunks {
                                            if !send_locked(&writer, &Frame::Trace(chunk)) {
                                                break;
                                            }
                                        }
                                    }
                                    Err(_) => {}
                                }
                            }
                        }
                        Frame::Snapshot { key, bytes } => {
                            snapshots.insert(key, bytes);
                        }
                        // Anything else at a worker is a protocol misuse
                        // by the parent; ignore rather than die.
                        _ => {}
                    }
                }
                Err(FrameError::Truncated { .. }) => break,
                Err(e) => return Err(format!("worker {}: framing lost: {e}", cfg.id)),
            }
        }
        match reader.read(&mut chunk) {
            Ok(0) => return Ok(()), // parent hung up: orderly exit
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(format!("worker {}: read: {e}", cfg.id)),
        }
    }
}

/// Resolve a [`TaskDesc`] against the local workload registry and run
/// the body (panics caught at the task boundary like any launcher).
/// Every failure mode answers with an `ok: false` frame — the worker
/// must outlive a bad descriptor.
fn execute_launch(
    cache: &mut HashMap<(String, u32), Box<dyn Workload>>,
    desc: &TaskDesc,
) -> Frame {
    let err = |msg: String| Frame::TaskResult {
        task_id: desc.task_id,
        ok: false,
        payload: msg.into_bytes(),
    };
    let key = (desc.workload.clone(), desc.scale_milli);
    if !cache.contains_key(&key) {
        match workloads::by_name(&desc.workload, desc.scale_milli as f64 / 1000.0) {
            Some(w) => {
                cache.insert(key.clone(), w);
            }
            None => return err(format!("unknown workload {:?}", desc.workload)),
        }
    }
    let w = &cache[&key];
    if desc.layer as usize >= w.layers() {
        return err(format!("layer {} out of range ({})", desc.layer, w.layers()));
    }
    let specs = w.layer_tasks(desc.layer as usize);
    let Some(spec) = specs.get(desc.index as usize) else {
        return err(format!("slot {} out of range ({})", desc.index, specs.len()));
    };
    let mut inputs: Vec<Chunk> = Vec::with_capacity(desc.inputs.len());
    for b in &desc.inputs {
        match Chunk::from_bytes(b) {
            Some(c) => inputs.push(c),
            None => return err("undecodable input chunk".into()),
        }
    }
    let body = Arc::clone(&spec.body);
    match crate::api::run_task_body(move || body(&inputs)) {
        Ok(vals) => Frame::TaskResult {
            task_id: desc.task_id,
            ok: true,
            payload: vals.to_bytes(),
        },
        Err(e) => err(e.to_string()),
    }
}

// ---------------------------------------------------------------------
// The parent side: ProcCluster
// ---------------------------------------------------------------------

/// How one remote call settled.
enum CallOutcome {
    Value(Vec<f64>),
    RemoteErr(String),
    /// The home locality was declared dead with the call in flight;
    /// carries the verdict instant so the re-sender can price recovery.
    Died(Instant),
}

struct PendingCall {
    loc: usize,
    promise: Promise<CallOutcome>,
}

struct WorkerSlot {
    child: Mutex<Option<Child>>,
    writer: Mutex<Option<TcpStream>>,
    /// Cleared only by the heartbeat verdict — a SIGKILL does *not*
    /// touch it, so detection stays honest.
    alive: AtomicBool,
    executed: AtomicUsize,
    rejected: AtomicUsize,
    lost: AtomicUsize,
}

struct ProcInner {
    spec: ProcSpec,
    workers: Vec<WorkerSlot>,
    pending: Mutex<HashMap<u64, PendingCall>>,
    next_task_id: AtomicU64,
    rr: AtomicUsize,
    monitor: Mutex<HeartbeatMonitor>,
    start: Instant,
    /// SIGKILL instants not yet matched by a verdict, per locality.
    kill_marks: Mutex<HashMap<usize, Instant>>,
    detection_secs: Mutex<Vec<f64>>,
    drain_secs: Mutex<Vec<f64>>,
    /// Schedule cursor (first unfired event index).
    fired: Mutex<usize>,
    stop: AtomicBool,
    monitor_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Trace chunks streamed live from workers, keyed by (locality,
    /// seq) so a post-mortem spool read can fill gaps without
    /// duplicating what already arrived.
    trace_chunks: Mutex<HashMap<(u32, u64), crate::trace::spool::TraceChunk>>,
}

impl ProcInner {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Round-robin target: resilient routes place over live workers only
    /// (`None` when the whole fleet is dead); bare routes keep the full
    /// ring, so a dead worker rejects its share at dispatch — the same
    /// split as [`ClusterExecutor::new`]/`alive_routed`.
    ///
    /// [`ClusterExecutor::new`]: super::ClusterExecutor::new
    fn pick(&self, alive_only: bool) -> Option<usize> {
        let n = self.workers.len();
        if !alive_only {
            return Some(self.rr.fetch_add(1, Ordering::Relaxed) % n);
        }
        for _ in 0..n {
            let i = self.rr.fetch_add(1, Ordering::Relaxed) % n;
            if self.workers[i].alive.load(Ordering::SeqCst) {
                return Some(i);
            }
        }
        None
    }

    fn send_to(&self, loc: usize, frame: &Frame) -> bool {
        if !self.workers[loc].alive.load(Ordering::SeqCst) {
            return false;
        }
        let mut guard = self.workers[loc].writer.lock().unwrap();
        match guard.as_mut() {
            Some(s) => s.write_all(&frame.encode()).is_ok(),
            None => false,
        }
    }

    /// Every frame is evidence of life (a worker streaming results with
    /// a starved beat thread is alive); results settle pending calls.
    fn on_frame(&self, loc: usize, frame: Frame) {
        let now = self.now_ms();
        self.monitor.lock().unwrap().beat(LocalityId(loc), now);
        match frame {
            Frame::TaskResult { task_id, ok, payload } => {
                self.workers[loc].executed.fetch_add(1, Ordering::Relaxed);
                let entry = self.pending.lock().unwrap().remove(&task_id);
                if let Some(p) = entry {
                    let outcome = if ok {
                        match Vec::<f64>::from_bytes(&payload) {
                            Some(v) => CallOutcome::Value(v),
                            None => CallOutcome::RemoteErr("undecodable result payload".into()),
                        }
                    } else {
                        CallOutcome::RemoteErr(String::from_utf8_lossy(&payload).into_owned())
                    };
                    p.promise.set_result(Ok(outcome));
                }
                // else: a stale result for a call already drained and
                // re-sent elsewhere — the first settlement won.
            }
            Frame::Trace(chunk) => {
                self.trace_chunks
                    .lock()
                    .unwrap()
                    .insert((chunk.locality, chunk.seq), chunk);
            }
            Frame::Counters { locality, counters } => {
                // Fold worker counters into the parent registry under a
                // per-locality prefix; gauges, since each snapshot is a
                // fresh absolute reading, not a delta.
                let reg = crate::perfcounters::global();
                for (name, value) in counters {
                    reg.gauge(&format!("/locality/{locality}{name}")).set(value);
                }
            }
            _ => {}
        }
    }

    /// The verdict fell on `loc`: stop routing there, price detection
    /// (when this was our own SIGKILL), and drain the corpse's in-flight
    /// calls so each can re-materialize on a survivor.
    fn on_death(&self, loc: usize) {
        self.workers[loc].alive.store(false, Ordering::SeqCst);
        let verdict = Instant::now();
        crate::trace::emit(crate::trace::EventKind::DeathVerdict, loc as u64, 0);
        if let Some(mark) = self.kill_marks.lock().unwrap().remove(&loc) {
            self.detection_secs.lock().unwrap().push((verdict - mark).as_secs_f64());
        }
        let drained: Vec<(u64, PendingCall)> = {
            let mut pending = self.pending.lock().unwrap();
            let ids: Vec<u64> =
                pending.iter().filter(|(_, p)| p.loc == loc).map(|(id, _)| *id).collect();
            ids.into_iter().filter_map(|id| pending.remove(&id).map(|p| (id, p))).collect()
        };
        for (task_id, p) in drained {
            self.workers[loc].lost.fetch_add(1, Ordering::Relaxed);
            crate::trace::emit(crate::trace::EventKind::Drain, loc as u64, task_id);
            p.promise.set_result(Ok(CallOutcome::Died(verdict)));
        }
    }
}

impl Drop for ProcInner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.monitor_thread.lock().unwrap().take() {
            let _ = h.join();
        }
        for slot in &self.workers {
            if let Some(mut child) = slot.child.lock().unwrap().take() {
                let _ = child.kill();
                let _ = child.wait(); // reap, no zombies
            }
        }
    }
}

/// A cluster of real worker processes, presenting the same routing
/// surface as the simulated [`Cluster`](super::Cluster): spawn workers,
/// route task launches, collect results into local [`Future`]s, report
/// per-locality placement/survival. Cloning shares the cluster;
/// dropping the last handle SIGKILLs and reaps every child.
#[derive(Clone)]
pub struct ProcCluster {
    inner: Arc<ProcInner>,
}

impl ProcCluster {
    /// Spawn the spec's workers and complete the hello handshake with
    /// each. Fails (killing anything already spawned) if any worker
    /// cannot start or does not report in.
    pub fn start(spec: &ProcSpec) -> Result<ProcCluster, String> {
        let bin = worker_binary()?;
        let listener =
            TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind parent socket: {e}"))?;
        let addr =
            listener.local_addr().map_err(|e| format!("parent socket addr: {e}"))?;

        let mut children: Vec<Child> = Vec::new();
        for i in 0..spec.localities {
            let mut cmd = Command::new(&bin);
            cmd.arg("worker")
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--id")
                .arg(i.to_string())
                .arg("--heartbeat-ms")
                .arg(spec.heartbeat_ms.to_string());
            if let Some(dir) = &spec.trace_spool {
                cmd.arg("--trace-spool").arg(dir);
            }
            if let Some(ev) = spec.crash {
                if ev.loc.0 == i {
                    cmd.arg("--crash-after").arg(ev.step.to_string());
                }
            }
            cmd.stdin(Stdio::null()).stdout(Stdio::null());
            match cmd.spawn() {
                Ok(c) => children.push(c),
                Err(e) => {
                    for mut c in children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    return Err(format!("spawn worker {i} ({}): {e}", bin.display()));
                }
            }
        }

        // Accept one hello per worker (any order); each connection's
        // first frame names its locality id.
        let mut conns: Vec<Option<(TcpStream, Vec<u8>)>> =
            (0..spec.localities).map(|_| None).collect();
        listener.set_nonblocking(true).ok();
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut accepted = 0usize;
        let fail = |children: Vec<Child>, msg: String| {
            for mut c in children {
                let _ = c.kill();
                let _ = c.wait();
            }
            Err(msg)
        };
        while accepted < spec.localities {
            if Instant::now() > deadline {
                return fail(children, "worker handshake timed out".into());
            }
            match listener.accept() {
                Ok((mut stream, _peer)) => {
                    stream.set_nodelay(true).ok();
                    stream.set_nonblocking(false).ok();
                    let (id, leftover) = match read_hello(&mut stream) {
                        Ok(x) => x,
                        Err(e) => return fail(children, e),
                    };
                    let slot = conns
                        .get_mut(id as usize)
                        .ok_or(())
                        .map_err(|_| format!("hello names locality {id} out of range"));
                    match slot {
                        Ok(s) if s.is_none() => *s = Some((stream, leftover)),
                        Ok(_) => return fail(children, format!("duplicate hello for locality {id}")),
                        Err(e) => return fail(children, e),
                    }
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return fail(children, format!("accept worker: {e}")),
            }
        }

        let start = Instant::now();
        let mut workers = Vec::with_capacity(spec.localities);
        let mut readers: Vec<(TcpStream, Vec<u8>)> = Vec::with_capacity(spec.localities);
        for (i, (conn, child)) in conns.into_iter().zip(children).enumerate() {
            let (stream, leftover) = conn.expect("all slots filled above");
            let writer = stream
                .try_clone()
                .map_err(|e| format!("clone worker {i} stream: {e}"))?;
            readers.push((stream, leftover));
            workers.push(WorkerSlot {
                child: Mutex::new(Some(child)),
                writer: Mutex::new(Some(writer)),
                alive: AtomicBool::new(true),
                executed: AtomicUsize::new(0),
                rejected: AtomicUsize::new(0),
                lost: AtomicUsize::new(0),
            });
        }

        let inner = Arc::new(ProcInner {
            workers,
            pending: Mutex::new(HashMap::new()),
            next_task_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            monitor: Mutex::new(HeartbeatMonitor::new(
                spec.localities,
                spec.heartbeat_ms,
                spec.k_missed,
                0,
            )),
            start,
            kill_marks: Mutex::new(HashMap::new()),
            detection_secs: Mutex::new(Vec::new()),
            drain_secs: Mutex::new(Vec::new()),
            fired: Mutex::new(0),
            stop: AtomicBool::new(false),
            monitor_thread: Mutex::new(None),
            trace_chunks: Mutex::new(HashMap::new()),
            spec: spec.clone(),
        });

        // Reader and monitor threads hold only weak handles: the last
        // strong handle's drop must run (it kills the children, whose
        // EOF in turn unblocks the readers).
        for (i, (stream, leftover)) in readers.into_iter().enumerate() {
            let weak = Arc::downgrade(&inner);
            std::thread::Builder::new()
                .name(format!("rhpx-proc-read-{i}"))
                .spawn(move || reader_loop(weak, i, stream, leftover))
                .map_err(|e| format!("spawn reader thread: {e}"))?;
        }
        let weak = Arc::downgrade(&inner);
        let tick = (spec.heartbeat_ms / 2).max(1);
        let handle = std::thread::Builder::new()
            .name("rhpx-proc-monitor".into())
            .spawn(move || monitor_loop(weak, tick))
            .map_err(|e| format!("spawn monitor thread: {e}"))?;
        *inner.monitor_thread.lock().unwrap() = Some(handle);

        Ok(ProcCluster { inner })
    }

    pub fn len(&self) -> usize {
        self.inner.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.workers.is_empty()
    }

    /// Workers not (yet) declared dead by the monitor.
    pub fn alive_len(&self) -> usize {
        self.inner
            .workers
            .iter()
            .filter(|w| w.alive.load(Ordering::SeqCst))
            .count()
    }

    /// One remote task execution, blocking until it settles.
    ///
    /// `resilient` selects the placement/recovery contract (mirroring
    /// the simulated substrate): resilient calls route over live workers
    /// only and transparently re-materialize on a survivor when their
    /// home dies mid-flight; bare calls use the full ring and surface
    /// both dead-dispatch rejections and in-flight deaths as errors — a
    /// poisoned slot, never a hang.
    pub fn call(&self, mut desc: TaskDesc, resilient: bool) -> TaskResult<Vec<f64>> {
        let inner = &*self.inner;
        let mut recovery_from: Option<Instant> = None;
        loop {
            let Some(loc) = inner.pick(resilient) else {
                return Err(TaskError::App("no live worker locality".into()));
            };
            let task_id = inner.next_task_id.fetch_add(1, Ordering::Relaxed);
            desc.task_id = task_id;
            let (promise, fut) = Promise::new();
            inner
                .pending
                .lock()
                .unwrap()
                .insert(task_id, PendingCall { loc, promise });
            if !inner.send_to(loc, &Frame::Launch(desc.clone())) {
                inner.pending.lock().unwrap().remove(&task_id);
                inner.workers[loc].rejected.fetch_add(1, Ordering::Relaxed);
                if resilient {
                    continue; // next live worker
                }
                return Err(TaskError::App(format!(
                    "locality {loc} is dead: task rejected at dispatch"
                )));
            }
            match fut.get() {
                Ok(CallOutcome::Value(v)) => {
                    if let Some(from) = recovery_from {
                        inner.drain_secs.lock().unwrap().push(from.elapsed().as_secs_f64());
                    }
                    return Ok(v);
                }
                Ok(CallOutcome::RemoteErr(m)) => return Err(TaskError::App(m)),
                Ok(CallOutcome::Died(verdict)) => {
                    if !resilient {
                        return Err(TaskError::App(format!(
                            "locality {loc} died with the task in flight"
                        )));
                    }
                    // Lineage re-materialization: the retained descriptor
                    // re-enters the loop and lands on a survivor.
                    crate::trace::emit(
                        crate::trace::EventKind::Rematerialize,
                        task_id,
                        loc as u64,
                    );
                    recovery_from.get_or_insert(verdict);
                }
                Err(e) => return Err(e), // broken promise: cluster shut down
            }
        }
    }

    /// `SIGKILL` a worker's real OS process. The heartbeat monitor — not
    /// this call — decides death, so detection latency is honest: the
    /// mark laid down here is matched against the eventual verdict.
    pub fn kill(&self, loc: LocalityId) {
        let inner = &*self.inner;
        if loc.0 >= inner.workers.len() {
            return;
        }
        inner.kill_marks.lock().unwrap().entry(loc.0).or_insert_with(Instant::now);
        if let Some(child) = inner.workers[loc.0].child.lock().unwrap().as_mut() {
            let _ = child.kill();
        }
    }

    /// Fire every scheduled `kill=` event with `step <= step` (the same
    /// driver-step clock as [`FaultSchedule::advance`], applied to real
    /// PIDs); returns the events fired now.
    pub fn advance_schedule(&self, step: usize) -> Vec<KillEvent> {
        let inner = &*self.inner;
        let events = inner.spec.schedule.events();
        let mut fired = Vec::new();
        let mut cursor = inner.fired.lock().unwrap();
        while *cursor < events.len() && events[*cursor].step <= step {
            let ev = events[*cursor];
            *cursor += 1;
            self.kill(ev.loc);
            fired.push(ev);
        }
        fired
    }

    /// Block until every SIGKILL laid down by [`ProcCluster::kill`] has
    /// been matched by a heartbeat verdict (or `timeout` passes): runs
    /// that finish before the detector fires still report an honest
    /// detection latency.
    pub fn settle_verdicts(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while !self.inner.kill_marks.lock().unwrap().is_empty() {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Kill→verdict times of settled SIGKILLs.
    pub fn detection_latency_secs(&self) -> Vec<f64> {
        self.inner.detection_secs.lock().unwrap().clone()
    }

    /// Verdict→re-completed times of re-materialized in-flight calls.
    pub fn drain_latency_secs(&self) -> Vec<f64> {
        self.inner.drain_secs.lock().unwrap().clone()
    }

    /// Mirror checkpoint bytes onto a live worker (fire-and-forget
    /// [`Frame::Snapshot`]); returns the locality that took it.
    pub fn mirror_snapshot(&self, key: &str, bytes: &[u8]) -> Option<usize> {
        let inner = &*self.inner;
        let loc = inner.pick(true)?;
        let frame = Frame::Snapshot { key: key.to_string(), bytes: bytes.to_vec() };
        inner.send_to(loc, &frame).then_some(loc)
    }

    /// Per-locality placement/survival breakdown, shaped exactly like
    /// the simulated route's so reports compare directly.
    pub fn locality_reports(&self, kills_applied: &[KillEvent]) -> Vec<LocalityReport> {
        self.inner
            .workers
            .iter()
            .enumerate()
            .map(|(i, w)| LocalityReport {
                id: i,
                tasks_executed: w.executed.load(Ordering::Relaxed),
                tasks_rejected: w.rejected.load(Ordering::Relaxed),
                tasks_lost: w.lost.load(Ordering::Relaxed),
                alive_at_end: w.alive.load(Ordering::SeqCst),
                killed_at_task: kills_applied.iter().find(|e| e.loc.0 == i).map(|e| e.step),
            })
            .collect()
    }

    /// The spec this cluster was started from.
    pub fn spec(&self) -> &ProcSpec {
        &self.inner.spec
    }

    /// Collect the cluster's trace: chunks streamed live from workers,
    /// merged with whatever their fsynced spool files hold. For a worker
    /// that died mid-task the spool supplies the final pre-death events
    /// its severed socket never delivered — the post-mortem case.
    /// Streamed chunks win ties (same bytes, already in memory).
    pub fn take_trace(&self) -> Vec<crate::trace::spool::TraceChunk> {
        let streamed: Vec<crate::trace::spool::TraceChunk> = {
            let mut held = self.inner.trace_chunks.lock().unwrap();
            std::mem::take(&mut *held).into_values().collect()
        };
        let spooled = match &self.inner.spec.trace_spool {
            Some(dir) => crate::trace::spool::read_spool_dir(dir),
            None => Vec::new(),
        };
        crate::trace::spool::merge_chunks(streamed, spooled)
    }
}

/// First frame of a fresh worker connection: `Heartbeat { locality,
/// seq: 0 }`. Returns the id plus any bytes already buffered past the
/// hello (handed to the reader thread so no frame is lost).
fn read_hello(stream: &mut TcpStream) -> Result<(u32, Vec<u8>), String> {
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        match Frame::decode(&buf) {
            Ok((Frame::Heartbeat { locality, .. }, consumed)) => {
                buf.drain(..consumed);
                return Ok((locality, buf));
            }
            Ok((f, _)) => return Err(format!("unexpected hello frame {f:?}")),
            Err(FrameError::Truncated { .. }) => {}
            Err(e) => return Err(format!("bad hello: {e}")),
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Err("worker hung up during handshake".into()),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(format!("handshake read: {e}")),
        }
    }
}

fn reader_loop(weak: Weak<ProcInner>, loc: usize, mut stream: TcpStream, mut buf: Vec<u8>) {
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
    let mut chunk = [0u8; 16384];
    loop {
        loop {
            match Frame::decode(&buf) {
                Ok((frame, consumed)) => {
                    buf.drain(..consumed);
                    let Some(inner) = weak.upgrade() else { return };
                    inner.on_frame(loc, frame);
                }
                Err(FrameError::Truncated { .. }) => break,
                Err(_) => return, // framing lost; silence → verdict
            }
        }
        if weak.upgrade().is_none() {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // worker gone; the monitor will notice
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return,
        }
    }
}

fn monitor_loop(weak: Weak<ProcInner>, tick_ms: u64) {
    let mut reported_misses: Vec<u64> = Vec::new();
    loop {
        std::thread::sleep(Duration::from_millis(tick_ms));
        let Some(inner) = weak.upgrade() else { return };
        if inner.stop.load(Ordering::SeqCst) {
            return;
        }
        let now = inner.now_ms();
        let newly_dead = {
            let mut mon = inner.monitor.lock().unwrap();
            // Each freshly crossed missed-period boundary becomes one
            // HeartbeatMiss instant, so a post-mortem timeline shows the
            // silence growing toward the verdict.
            reported_misses.resize(inner.workers.len(), 0);
            for i in 0..inner.workers.len() {
                let missed = mon.missed_periods(LocalityId(i), now);
                if missed > reported_misses[i] {
                    crate::trace::emit(
                        crate::trace::EventKind::HeartbeatMiss,
                        i as u64,
                        missed,
                    );
                    reported_misses[i] = missed;
                } else if missed < reported_misses[i] {
                    reported_misses[i] = missed; // beat arrived: reset
                }
            }
            mon.poll(now)
        };
        for id in newly_dead {
            inner.on_death(id.0);
        }
    }
}

// ---------------------------------------------------------------------
// ProcExec — the TaskLauncher over the process substrate
// ---------------------------------------------------------------------

/// [`TaskLauncher`] over a [`ProcCluster`]: each submitted body (a
/// blocking remote call built by [`RemoteWorkload`]) runs on a dedicated
/// thread, so the decorators' concurrency model — futures resolve as
/// attempts finish — carries over unchanged from the pool substrate.
#[derive(Clone)]
pub struct ProcExec {
    cluster: ProcCluster,
}

impl ProcExec {
    pub fn new(cluster: &ProcCluster) -> Self {
        ProcExec { cluster: cluster.clone() }
    }

    pub fn cluster(&self) -> &ProcCluster {
        &self.cluster
    }
}

impl TaskLauncher for ProcExec {
    fn submit<T: Send + 'static>(&self, body: TaskFn<T>) -> Future<T> {
        let (p, fut) = Promise::new();
        std::thread::Builder::new()
            .name("rhpx-proc-call".into())
            .spawn(move || p.set_result(crate::api::run_task_body(move || body())))
            .expect("spawn proc call thread");
        fut
    }

    fn parallelism(&self) -> usize {
        self.cluster.len()
    }

    fn base_label(&self) -> String {
        format!("proc({})", self.cluster.len())
    }
}

// ---------------------------------------------------------------------
// RemoteWorkload — ship bodies by name over the wire
// ---------------------------------------------------------------------

/// A [`Workload`] whose task bodies are remote calls: same DAG shape as
/// the wrapped workload (deps, widths, windows — the parent still owns
/// dependency resolution, fault wiring, and validation), but each body
/// encodes its input chunks into a [`TaskDesc`] and executes on
/// whichever worker process [`ProcCluster::call`] routes it to.
pub struct RemoteWorkload {
    inner: Box<dyn Workload>,
    cluster: ProcCluster,
    scale_milli: u32,
    resilient: bool,
}

impl RemoteWorkload {
    /// Build the parent-side twin of what the workers will rebuild:
    /// both sides construct `name` at `spec.scale_milli / 1000`, making
    /// the layer/slot indices on the wire unambiguous.
    pub fn from_spec(
        name: &str,
        spec: &ProcSpec,
        cluster: &ProcCluster,
        resilient: bool,
    ) -> Option<RemoteWorkload> {
        let inner = workloads::by_name(name, spec.scale_milli as f64 / 1000.0)?;
        Some(RemoteWorkload {
            inner,
            cluster: cluster.clone(),
            scale_milli: spec.scale_milli,
            resilient,
        })
    }
}

impl Workload for RemoteWorkload {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn describe(&self) -> &'static str {
        self.inner.describe()
    }

    fn initial(&self) -> Vec<Chunk> {
        self.inner.initial()
    }

    fn layers(&self) -> usize {
        self.inner.layers()
    }

    fn layer_tasks(&self, layer: usize) -> Vec<TaskSpec> {
        let name = self.inner.name();
        self.inner
            .layer_tasks(layer)
            .into_iter()
            .enumerate()
            .map(|(index, spec)| {
                let cluster = self.cluster.clone();
                let (scale_milli, resilient) = (self.scale_milli, self.resilient);
                TaskSpec::new(spec.deps, move |vals: &[Chunk]| {
                    let desc = TaskDesc {
                        task_id: 0, // assigned per attempt by call()
                        workload: name.to_string(),
                        scale_milli,
                        layer: layer as u32,
                        index: index as u32,
                        inputs: vals.iter().map(|c| c.to_bytes()).collect(),
                    };
                    cluster.call(desc, resilient)
                })
            })
            .collect()
    }

    fn window(&self) -> usize {
        self.inner.window()
    }

    fn tol(&self) -> f64 {
        self.inner.tol()
    }
}

// ---------------------------------------------------------------------
// ProcMirrorStore — checkpoint snapshots over the wire
// ---------------------------------------------------------------------

/// Checkpoint backend for the proc route: the parent keeps the
/// authoritative copy in memory and mirrors every save onto a live
/// worker as a [`Frame::Snapshot`]; a locality death re-mirrors the
/// corpse's keys to a survivor — the same re-homing choreography as
/// [`AgasSnapshotStore`](crate::resilience::checkpoint::AgasSnapshotStore),
/// exercised over a real wire. (Parent authority means nothing is ever
/// irrecoverably lost; `lost()` stays 0 by construction.)
pub struct ProcMirrorStore {
    inner: MemorySnapshotStore,
    cluster: ProcCluster,
    /// key → locality currently holding the mirror.
    homes: Mutex<HashMap<String, usize>>,
}

impl ProcMirrorStore {
    pub fn new(cluster: &ProcCluster) -> Self {
        ProcMirrorStore {
            inner: MemorySnapshotStore::new(),
            cluster: cluster.clone(),
            homes: Mutex::new(HashMap::new()),
        }
    }
}

impl SnapshotStore for ProcMirrorStore {
    fn save(&self, key: &str, bytes: &[u8]) -> TaskResult<()> {
        self.inner.save(key, bytes)?;
        if let Some(loc) = self.cluster.mirror_snapshot(key, bytes) {
            self.homes.lock().unwrap().insert(key.to_string(), loc);
        }
        Ok(())
    }

    fn load(&self, key: &str) -> Option<Vec<u8>> {
        self.inner.load(key)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }

    fn remove(&self, key: &str) -> bool {
        self.homes.lock().unwrap().remove(key);
        self.inner.remove(key)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn on_locality_killed(&self, loc: LocalityId) {
        let orphaned: Vec<String> = {
            let homes = self.homes.lock().unwrap();
            homes
                .iter()
                .filter(|(_, l)| **l == loc.0)
                .map(|(k, _)| k.clone())
                .collect()
        };
        for key in orphaned {
            if let Some(bytes) = self.inner.load(&key) {
                match self.cluster.mirror_snapshot(&key, &bytes) {
                    Some(new_loc) => {
                        self.homes.lock().unwrap().insert(key, new_loc);
                    }
                    None => {
                        self.homes.lock().unwrap().remove(&key);
                    }
                }
            }
        }
    }

    fn label(&self) -> String {
        format!("proc-mirror(mem x{})", self.cluster.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monitor_declares_dead_exactly_at_k_missed_periods() {
        let mut m = HeartbeatMonitor::new(2, 10, 3, 0);
        m.beat(LocalityId(0), 5);
        m.beat(LocalityId(1), 5);
        assert!(m.poll(34).is_empty(), "one tick short of the deadline");
        let dead = m.poll(35); // 5 + 3*10: exactly K missed periods
        assert_eq!(dead, vec![LocalityId(0), LocalityId(1)]);
        assert!(m.poll(100).is_empty(), "a verdict is reported once");
        assert!(m.is_dead(LocalityId(0)));
        assert!(m.alive_ids().is_empty());
    }

    #[test]
    fn monitor_death_is_final_and_late_beats_are_ignored() {
        let mut m = HeartbeatMonitor::new(1, 10, 2, 0);
        assert_eq!(m.poll(20), vec![LocalityId(0)]);
        assert!(!m.beat(LocalityId(0), 21), "late beat loses the race");
        assert!(m.is_dead(LocalityId(0)));
        assert!(m.poll(1000).is_empty());
    }

    #[test]
    fn monitor_slow_but_alive_worker_is_never_declared() {
        let mut m = HeartbeatMonitor::new(1, 10, 3, 0);
        // Beats arrive late every time — 29 ms gaps against a 30 ms
        // deadline — but always inside it.
        for t in [29u64, 58, 87, 116] {
            assert!(m.poll(t).is_empty(), "no false positive at {t}");
            assert!(m.beat(LocalityId(0), t));
        }
        assert!(!m.is_dead(LocalityId(0)));
    }

    #[test]
    fn monitor_out_of_range_locality_is_harmless() {
        let mut m = HeartbeatMonitor::new(1, 10, 2, 0);
        assert!(!m.beat(LocalityId(7), 5));
        assert!(!m.is_dead(LocalityId(7)));
    }

    #[test]
    fn proc_spec_parses_kills_and_crash() {
        let s = ProcSpec::parse("3").unwrap();
        assert_eq!(s.localities, 3);
        assert!(s.schedule.is_empty());
        assert!(s.crash.is_none());
        assert_eq!(s.heartbeat_ms, DEFAULT_HEARTBEAT_MS);

        let s = ProcSpec::parse("4:kill=10@2,kill=3@1").unwrap();
        assert_eq!(s.schedule.events().len(), 2);
        assert_eq!(s.schedule.events()[0].step, 3, "sorted by step");

        let s = ProcSpec::parse("3:kill=6@1,crash=2@0").unwrap();
        assert_eq!(s.schedule.events().len(), 1);
        assert_eq!(s.crash, Some(KillEvent { step: 2, loc: LocalityId(0) }));

        assert!(ProcSpec::parse("0").is_err());
        assert!(ProcSpec::parse("3:kill=1@9").is_err());
        assert!(ProcSpec::parse("3:crash=0@0").is_err(), "crash count is 1-based");
        assert!(ProcSpec::parse("3:crash=1@0,crash=2@1").is_err());
        assert!(ProcSpec::parse("3:bogus=1@0").is_err());
    }

    #[test]
    fn worker_binary_honors_the_env_override() {
        // Env mutation: keyed uniquely enough not to race other tests.
        std::env::set_var("RHPX_WORKER_BIN", "/tmp/rhpx-test-override");
        let got = worker_binary().unwrap();
        std::env::remove_var("RHPX_WORKER_BIN");
        assert_eq!(got, PathBuf::from("/tmp/rhpx-test-override"));
    }
}
