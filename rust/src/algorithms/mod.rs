//! Parallel algorithms over an [`Executor`](crate::executor::Executor) —
//! the HPX "higher-level parallelization" layer (standards-style
//! `for_each` / `transform` / `reduce`), made resilient by executor
//! choice: run them on a [`ReplayExecutor`](crate::executor::ReplayExecutor)
//! and every chunk transparently replays on failure.
//!
//! Paper mapping: §Future-Work "higher-level parallelization facilities"
//! over the resilient executors (no table/figure of its own).

use std::sync::Arc;

use crate::error::{TaskError, TaskResult};
use crate::executor::Executor;
use crate::future::Future;

/// Chunk `[0, len)` into roughly `4 × concurrency` ranges (enough slack
/// for work stealing without drowning in per-task overhead).
fn chunks(len: usize, concurrency: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return Vec::new();
    }
    let target = (concurrency.max(1) * 4).min(len);
    let size = len.div_ceil(target);
    (0..len.div_ceil(size))
        .map(|i| (i * size, ((i + 1) * size).min(len)))
        .collect()
}

/// Parallel `transform`: `out[i] = f(&items[i])`, order-preserving.
///
/// `f` may fail per element; a failing element fails its chunk, which
/// the executor's policy handles (replay/replicate). The first
/// irrecoverable chunk error aborts the whole transform.
pub fn par_transform<E, T, U, F>(ex: &E, items: Vec<T>, f: F) -> TaskResult<Vec<U>>
where
    E: Executor,
    T: Send + Sync + 'static,
    U: Clone + Send + 'static,
    F: Fn(&T) -> TaskResult<U> + Send + Sync + 'static,
{
    let items = Arc::new(items);
    let f = Arc::new(f);
    let futs: Vec<(usize, Future<Vec<U>>)> = chunks(items.len(), ex.concurrency())
        .into_iter()
        .map(|(lo, hi)| {
            let items = Arc::clone(&items);
            let f = Arc::clone(&f);
            (
                lo,
                ex.execute(move || items[lo..hi].iter().map(|x| f(x)).collect()),
            )
        })
        .collect();
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    for (lo, fut) in futs {
        for (i, v) in fut.get()?.into_iter().enumerate() {
            out[lo + i] = Some(v);
        }
    }
    Ok(out.into_iter().map(|v| v.expect("all chunks filled")).collect())
}

/// Parallel `for_each`: run `f` over every element for its side effects.
pub fn par_for_each<E, T, F>(ex: &E, items: Vec<T>, f: F) -> TaskResult<()>
where
    E: Executor,
    T: Send + Sync + 'static,
    F: Fn(&T) -> TaskResult<()> + Send + Sync + 'static,
{
    par_transform(ex, items, f).map(|_| ())
}

/// Parallel `reduce`: fold chunks in parallel with `f`, then combine the
/// per-chunk partials sequentially (deterministic for associative `f`
/// regardless of completion order).
pub fn par_reduce<E, T, F>(ex: &E, items: Vec<T>, identity: T, f: F) -> TaskResult<T>
where
    E: Executor,
    T: Clone + Send + Sync + 'static,
    F: Fn(&T, &T) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let items = Arc::new(items);
    let futs: Vec<Future<T>> = chunks(items.len(), ex.concurrency())
        .into_iter()
        .map(|(lo, hi)| {
            let items = Arc::clone(&items);
            let f = Arc::clone(&f);
            let id = identity.clone();
            ex.execute(move || {
                Ok(items[lo..hi].iter().fold(id.clone(), |acc, x| f(&acc, x)))
            })
        })
        .collect();
    let mut acc = identity;
    for fut in futs {
        let part = fut.get()?;
        acc = f(&acc, &part);
    }
    Ok(acc)
}

/// Parallel `count_if`.
pub fn par_count_if<E, T, F>(ex: &E, items: Vec<T>, pred: F) -> TaskResult<usize>
where
    E: Executor,
    T: Send + Sync + 'static,
    F: Fn(&T) -> bool + Send + Sync + 'static,
{
    let flags = par_transform(ex, items, move |x| Ok(usize::from(pred(x))))?;
    Ok(flags.iter().sum())
}

/// Map-reduce in one pass: transform each element, combine partials.
pub fn par_map_reduce<E, T, U, M, F>(
    ex: &E,
    items: Vec<T>,
    map: M,
    identity: U,
    combine: F,
) -> TaskResult<U>
where
    E: Executor,
    T: Send + Sync + 'static,
    U: Clone + Send + Sync + 'static,
    M: Fn(&T) -> TaskResult<U> + Send + Sync + 'static,
    F: Fn(&U, &U) -> U + Send + Sync + 'static,
{
    let map = Arc::new(map);
    let combine = Arc::new(combine);
    let items = Arc::new(items);
    let futs: Vec<Future<U>> = chunks(items.len(), ex.concurrency())
        .into_iter()
        .map(|(lo, hi)| {
            let items = Arc::clone(&items);
            let map = Arc::clone(&map);
            let combine = Arc::clone(&combine);
            let id = identity.clone();
            ex.execute(move || {
                let mut acc = id.clone();
                for x in &items[lo..hi] {
                    acc = combine(&acc, &map(x)?);
                }
                Ok(acc)
            })
        })
        .collect();
    let mut acc = identity;
    for fut in futs {
        acc = combine(&acc, &fut.get()?);
    }
    Ok(acc)
}

/// Convenience error for algorithm users.
pub fn abort<T>(msg: &str) -> TaskResult<T> {
    Err(TaskError::App(msg.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executor::{PlainExecutor, ReplayExecutor};
    use crate::failure::FaultInjector;
    use crate::runtime_handle::Runtime;

    fn rt() -> Runtime {
        Runtime::builder().workers(3).build()
    }

    #[test]
    fn chunks_cover_range_exactly() {
        for len in [0usize, 1, 7, 100, 1001] {
            for conc in [1usize, 2, 8] {
                let cs = chunks(len, conc);
                let mut covered = 0;
                let mut expect_lo = 0;
                for (lo, hi) in cs {
                    assert_eq!(lo, expect_lo);
                    assert!(hi > lo);
                    covered += hi - lo;
                    expect_lo = hi;
                }
                assert_eq!(covered, len, "len={len} conc={conc}");
            }
        }
    }

    #[test]
    fn transform_preserves_order() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let out = par_transform(&ex, (0..1000i64).collect(), |x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..1000i64).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn for_each_runs_every_element() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        par_for_each(&ex, (0..500).collect::<Vec<i32>>(), move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 500);
    }

    #[test]
    fn reduce_sums() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let sum = par_reduce(&ex, (1..=100i64).collect(), 0, |a, b| a + b).unwrap();
        assert_eq!(sum, 5050);
    }

    #[test]
    fn count_if_counts() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let n = par_count_if(&ex, (0..1000i64).collect(), |x| x % 3 == 0).unwrap();
        assert_eq!(n, 334);
    }

    #[test]
    fn map_reduce_composes() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let sum_sq =
            par_map_reduce(&ex, (1..=10i64).collect(), |x| Ok(x * x), 0, |a, b| a + b).unwrap();
        assert_eq!(sum_sq, 385);
    }

    #[test]
    fn resilient_transform_survives_failures() {
        // Under a ReplayExecutor, chunks hit by injected failures replay
        // until clean — the algorithm is failure-oblivious. NB the replay
        // unit is the *chunk* (~170 elements here), so the per-element
        // rate must keep P(chunk clean) reasonable: p = 0.002 →
        // P(chunk fails) ≈ 1 − 0.998^170 ≈ 0.29, trivially absorbed by
        // 50 retries.
        let rt = rt();
        let ex = ReplayExecutor::new(&rt, 50);
        let inj = FaultInjector::with_probability(0.002, 5);
        let out = par_transform(&ex, (0..2000i64).collect(), move |x| {
            inj.draw("par")?;
            Ok(x + 1)
        })
        .unwrap();
        assert_eq!(out, (1..=2000i64).collect::<Vec<_>>());
    }

    #[test]
    fn plain_transform_fails_without_resilience() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let inj = FaultInjector::with_probability(0.50, 5);
        let result = par_transform(&ex, (0..2000i64).collect(), move |x| {
            inj.draw("par")?;
            Ok(x + 1)
        });
        assert!(result.is_err(), "50% failures with no resilience must fail");
    }

    #[test]
    fn empty_input() {
        let rt = rt();
        let ex = PlainExecutor::new(&rt);
        let out: Vec<i64> = par_transform(&ex, Vec::<i64>::new(), |x| Ok(*x)).unwrap();
        assert!(out.is_empty());
        assert_eq!(par_reduce(&ex, Vec::<i64>::new(), 7, |a, b| a + b).unwrap(), 7);
    }
}
