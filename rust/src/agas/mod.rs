//! AGAS — an Active Global Address Space object registry.
//!
//! HPX component (2): "an active global address space that supports load
//! balancing via object migration". Components are registered under
//! globally unique ids ([`Gid`]); lookups resolve to the owning locality
//! plus the object; [`Agas::migrate`] atomically re-homes an object to
//! another locality. The distributed layer (see [`crate::distributed`])
//! uses this registry to route active messages to wherever an object
//! currently lives, and the task-level checkpoint subsystem
//! ([`crate::resilience::checkpoint`]) registers snapshot replicas here
//! ([`Agas::register_replicated`]) so they survive the owning locality's
//! death and can be re-homed via [`Agas::migrate`].
//!
//! Paper mapping: HPX runtime substrate (no table/figure of its own);
//! exercised by the §Future-Work distributed scenarios.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Globally unique id of a registered component.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Gid(pub u64);

/// Locality (node) identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LocalityId(pub usize);

/// A registered component: any `Send + Sync` object behind an `Arc`.
pub type Component = Arc<dyn Any + Send + Sync>;

struct Entry {
    home: LocalityId,
    object: Component,
    generation: u64,
}

/// The registry. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Agas {
    inner: Arc<AgasInner>,
}

struct AgasInner {
    next_gid: AtomicU64,
    entries: RwLock<HashMap<Gid, Mutex<Entry>>>,
    migrations: AtomicU64,
}

impl Default for Agas {
    fn default() -> Self {
        Self::new()
    }
}

impl Agas {
    pub fn new() -> Self {
        Agas {
            inner: Arc::new(AgasInner {
                next_gid: AtomicU64::new(1),
                entries: RwLock::new(HashMap::new()),
                migrations: AtomicU64::new(0),
            }),
        }
    }

    /// Register `object` on `home`, returning its new global id.
    pub fn register<T: Any + Send + Sync>(&self, home: LocalityId, object: T) -> Gid {
        let gid = Gid(self.inner.next_gid.fetch_add(1, Ordering::Relaxed));
        self.inner.entries.write().unwrap().insert(
            gid,
            Mutex::new(Entry { home, object: Arc::new(object), generation: 0 }),
        );
        gid
    }

    /// Typed replicated registration: one clone of `object` per home in
    /// `homes`, each under its own [`Gid`]. This is the replication
    /// primitive of the AGAS-backed snapshot store
    /// ([`crate::resilience::checkpoint::AgasSnapshotStore`]): with the
    /// replicas homed on distinct localities, a single locality death
    /// can touch at most one of them.
    pub fn register_replicated<T: Any + Send + Sync + Clone>(
        &self,
        homes: &[LocalityId],
        object: T,
    ) -> Vec<Gid> {
        homes.iter().map(|home| self.register(*home, object.clone())).collect()
    }

    /// Drop a registration; returns true if it existed.
    pub fn unregister(&self, gid: Gid) -> bool {
        self.inner.entries.write().unwrap().remove(&gid).is_some()
    }

    /// The locality an object currently lives on.
    pub fn locate(&self, gid: Gid) -> Option<LocalityId> {
        self.inner
            .entries
            .read()
            .unwrap()
            .get(&gid)
            .map(|e| e.lock().unwrap().home)
    }

    /// Resolve an object (typed). `None` if missing or of another type.
    pub fn resolve<T: Any + Send + Sync>(&self, gid: Gid) -> Option<Arc<T>> {
        let guard = self.inner.entries.read().unwrap();
        let entry = guard.get(&gid)?;
        let obj = entry.lock().unwrap().object.clone();
        obj.downcast::<T>().ok()
    }

    /// Atomically move an object to a new home locality (the AGAS
    /// "migration for load balancing" hook). Returns the previous home.
    pub fn migrate(&self, gid: Gid, to: LocalityId) -> Option<LocalityId> {
        let guard = self.inner.entries.read().unwrap();
        let entry = guard.get(&gid)?;
        let mut e = entry.lock().unwrap();
        let prev = e.home;
        e.home = to;
        e.generation += 1;
        self.inner.migrations.fetch_add(1, Ordering::Relaxed);
        Some(prev)
    }

    /// Number of completed migrations (metrics).
    pub fn migrations(&self) -> u64 {
        self.inner.migrations.load(Ordering::Relaxed)
    }

    /// Number of live registrations.
    pub fn len(&self) -> usize {
        self.inner.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Generation counter of an object (bumps on each migration).
    pub fn generation(&self, gid: Gid) -> Option<u64> {
        self.inner
            .entries
            .read()
            .unwrap()
            .get(&gid)
            .map(|e| e.lock().unwrap().generation)
    }

    /// Home and generation read *atomically* (one entry-lock critical
    /// section). Separate [`Agas::locate`] + [`Agas::generation`] calls
    /// can interleave with a concurrent [`Agas::migrate`] and pair a new
    /// home with a stale generation; resolvers that cache by generation
    /// (and the concurrency stress tests) need the consistent pair.
    pub fn locate_with_generation(&self, gid: Gid) -> Option<(LocalityId, u64)> {
        self.inner.entries.read().unwrap().get(&gid).map(|e| {
            let g = e.lock().unwrap();
            (g.home, g.generation)
        })
    }

    /// Gids currently homed on `loc` (membership accounting: what a
    /// locality death would take down if nothing re-homes it first).
    pub fn gids_homed_on(&self, loc: LocalityId) -> Vec<Gid> {
        self.inner
            .entries
            .read()
            .unwrap()
            .iter()
            .filter(|(_, e)| e.lock().unwrap().home == loc)
            .map(|(gid, _)| *gid)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_resolve_roundtrip() {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), 42i64);
        assert_eq!(agas.locate(gid), Some(LocalityId(0)));
        assert_eq!(*agas.resolve::<i64>(gid).unwrap(), 42);
        assert_eq!(agas.len(), 1);
    }

    #[test]
    fn resolve_wrong_type_is_none() {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), "hello".to_string());
        assert!(agas.resolve::<i64>(gid).is_none());
        assert!(agas.resolve::<String>(gid).is_some());
    }

    #[test]
    fn unregister_removes() {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), 1u8);
        assert!(agas.unregister(gid));
        assert!(!agas.unregister(gid));
        assert_eq!(agas.locate(gid), None);
        assert!(agas.is_empty());
    }

    #[test]
    fn migrate_rehomes_and_bumps_generation() {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), vec![1, 2, 3]);
        assert_eq!(agas.generation(gid), Some(0));
        let prev = agas.migrate(gid, LocalityId(3)).unwrap();
        assert_eq!(prev, LocalityId(0));
        assert_eq!(agas.locate(gid), Some(LocalityId(3)));
        assert_eq!(agas.generation(gid), Some(1));
        assert_eq!(agas.migrations(), 1);
        // object still resolvable after migration
        assert_eq!(*agas.resolve::<Vec<i32>>(gid).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn register_replicated_places_one_copy_per_home() {
        let agas = Agas::new();
        let homes = [LocalityId(0), LocalityId(2), LocalityId(3)];
        let gids = agas.register_replicated(&homes, vec![1.0f64, 2.0]);
        assert_eq!(gids.len(), 3);
        for (gid, home) in gids.iter().zip(homes.iter()) {
            assert_eq!(agas.locate(*gid), Some(*home));
            assert_eq!(*agas.resolve::<Vec<f64>>(*gid).unwrap(), vec![1.0, 2.0]);
        }
        assert_eq!(agas.gids_homed_on(LocalityId(2)), vec![gids[1]]);
        assert!(agas.gids_homed_on(LocalityId(7)).is_empty());
    }

    #[test]
    fn locate_with_generation_is_consistent_after_migrations() {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), 0u8);
        assert_eq!(agas.locate_with_generation(gid), Some((LocalityId(0), 0)));
        agas.migrate(gid, LocalityId(5));
        agas.migrate(gid, LocalityId(1));
        assert_eq!(agas.locate_with_generation(gid), Some((LocalityId(1), 2)));
        assert_eq!(agas.locate_with_generation(Gid(999)), None);
    }

    #[test]
    fn gids_are_unique_across_threads() {
        let agas = Agas::new();
        let mut handles = Vec::new();
        for t in 0..4 {
            let a = agas.clone();
            handles.push(std::thread::spawn(move || {
                (0..100).map(|_| a.register(LocalityId(t), 0u8)).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<Gid> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate gids issued");
    }
}
