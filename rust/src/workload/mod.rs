//! The artificial workload benchmark (§V-A, Listing 3).
//!
//! "This benchmark was written in order for the user to precisely control
//! the task grain size and therefore correctly compute the overheads of
//! the resiliency implementation." A task busy-waits for a configurable
//! grain, probabilistically throws per the exponential error model, and
//! returns 42; the harness launches it through each API variant and
//! amortizes the wall time over the number of tasks.

use crate::error::{TaskError, TaskResult};
use crate::failure::FaultInjector;
use crate::future::Future;
use crate::metrics::{busy_wait_ns, Timer};
use crate::resilience;
use crate::runtime_handle::Runtime;

/// Listing 3's `universal_ans`: busy-wait `delay_ns`, fail per the
/// injector's exponential model (decided *before* the wait, as in the
/// paper, so a failing task still consumes its grain), return 42.
pub fn universal_ans(delay_ns: u64, injector: &FaultInjector) -> TaskResult<i32> {
    let failed = injector.should_fail();
    busy_wait_ns(delay_ns);
    if failed {
        Err(TaskError::Injected { site: "universal_ans" })
    } else {
        Ok(42)
    }
}

/// Which launch API a workload run exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Plain `async_` — the non-resilient baseline.
    Plain,
    /// `async_replay(n)`.
    Replay { n: usize },
    /// `async_replay_validate(n)` (validates result == 42).
    ReplayValidate { n: usize },
    /// `async_replicate(n)`.
    Replicate { n: usize },
    /// `async_replicate_validate(n)`.
    ReplicateValidate { n: usize },
    /// `async_replicate_vote(n)` with majority voting.
    ReplicateVote { n: usize },
    /// `async_replicate_vote_validate(n)`.
    ReplicateVoteValidate { n: usize },
}

impl Variant {
    pub fn label(&self) -> String {
        match self {
            Variant::Plain => "async".to_string(),
            Variant::Replay { n } => format!("async_replay({n})"),
            Variant::ReplayValidate { n } => format!("async_replay_validate({n})"),
            Variant::Replicate { n } => format!("async_replicate({n})"),
            Variant::ReplicateValidate { n } => format!("async_replicate_validate({n})"),
            Variant::ReplicateVote { n } => format!("async_replicate_vote({n})"),
            Variant::ReplicateVoteValidate { n } => {
                format!("async_replicate_vote_validate({n})")
            }
        }
    }

    /// All six resilient variants of Table I at replication factor `n`.
    pub fn table1_variants(n: usize) -> Vec<Variant> {
        vec![
            Variant::Replay { n },
            Variant::ReplayValidate { n },
            Variant::Replicate { n },
            Variant::ReplicateValidate { n },
            Variant::ReplicateVote { n },
            Variant::ReplicateVoteValidate { n },
        ]
    }

    /// The declarative policy this variant is a *view* of.
    ///
    /// [`PolicySpec`](crate::resilience::executor::PolicySpec) is the
    /// single source of truth for what a strategy *is* (family, budget,
    /// compute multiplier, spec-string grammar); `Variant` only adds
    /// the per-call API dressing Table I distinguishes — whether the
    /// launch validates ([`Variant::validates`]) and/or votes
    /// ([`Variant::votes`]) on top of the base policy. `Plain` maps to
    /// `None` (no resilience at all).
    pub fn policy(&self) -> Option<crate::resilience::executor::PolicySpec> {
        use crate::resilience::executor::PolicySpec;
        match *self {
            Variant::Plain => None,
            Variant::Replay { n } | Variant::ReplayValidate { n } => {
                Some(PolicySpec::Replay { n })
            }
            Variant::Replicate { n }
            | Variant::ReplicateValidate { n }
            | Variant::ReplicateVote { n }
            | Variant::ReplicateVoteValidate { n } => Some(PolicySpec::Replicate { n }),
        }
    }

    /// True when the launch re-checks results against the expected
    /// answer (the `_validate` API variants).
    pub fn validates(&self) -> bool {
        matches!(
            self,
            Variant::ReplayValidate { .. }
                | Variant::ReplicateValidate { .. }
                | Variant::ReplicateVoteValidate { .. }
        )
    }

    /// True when replicas are reduced by majority vote (the `_vote` API
    /// variants).
    pub fn votes(&self) -> bool {
        matches!(
            self,
            Variant::ReplicateVote { .. } | Variant::ReplicateVoteValidate { .. }
        )
    }

    /// True for the replicate family (affects the compute multiplier) —
    /// derived from the underlying policy, not re-enumerated here.
    pub fn is_replicate(&self) -> bool {
        use crate::resilience::executor::PolicySpec;
        matches!(self.policy(), Some(PolicySpec::Replicate { .. }))
    }

    /// Eager duplicated compute per launch — delegated to the policy's
    /// [`compute_multiplier`](crate::resilience::executor::PolicySpec::compute_multiplier)
    /// so the free-function path and the executor path can never
    /// disagree on ideal-time accounting.
    pub fn compute_multiplier(&self) -> usize {
        self.policy().map_or(1, |p| p.compute_multiplier())
    }
}

/// Parameters of one workload run.
#[derive(Debug, Clone)]
pub struct WorkloadParams {
    /// Number of top-level task launches (paper: 1,000,000).
    pub tasks: usize,
    /// Task grain in nanoseconds (paper: 200 µs = 200_000).
    pub grain_ns: u64,
    /// Error-rate factor x with P(error) = e^{-x}; `None` disables.
    pub error_rate: Option<f64>,
    /// RNG seed for the injector.
    pub seed: u64,
    /// How many launches are in flight before the harness starts
    /// retiring them (bounds memory at the paper's 1M-task scale).
    pub window: usize,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        WorkloadParams {
            tasks: 100_000,
            grain_ns: 200_000,
            error_rate: None,
            seed: 0x5EED,
            window: 4096,
        }
    }
}

/// Result of one workload run.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub variant: String,
    pub tasks: usize,
    pub wall_secs: f64,
    /// Wall time per task in µs (the paper's amortized unit).
    pub per_task_us: f64,
    /// Amortized *overhead* per task vs. the ideal packed grain, in µs.
    pub overhead_us: f64,
    /// Percentage extra execution time vs. ideal grain time.
    pub overhead_pct: f64,
    pub failures_injected: u64,
    pub launch_errors: u64,
}

/// Launch one task through `variant`.
pub fn launch(
    rt: &Runtime,
    variant: Variant,
    grain_ns: u64,
    injector: &FaultInjector,
) -> Future<i32> {
    let inj = injector.clone();
    let body = move || universal_ans(grain_ns, &inj);
    let validate = |v: &i32| *v == 42;
    match variant {
        Variant::Plain => crate::api::async_(rt, body),
        Variant::Replay { n } => resilience::async_replay(rt, n, body),
        Variant::ReplayValidate { n } => {
            resilience::async_replay_validate(rt, n, validate, body)
        }
        Variant::Replicate { n } => resilience::async_replicate(rt, n, body),
        Variant::ReplicateValidate { n } => {
            resilience::async_replicate_validate(rt, n, validate, body)
        }
        Variant::ReplicateVote { n } => {
            resilience::async_replicate_vote(rt, n, resilience::vote_majority, body)
        }
        Variant::ReplicateVoteValidate { n } => resilience::async_replicate_vote_validate(
            rt,
            n,
            resilience::vote_majority,
            validate,
            body,
        ),
    }
}

/// Run the workload: `params.tasks` launches of `variant`, windowed so at
/// most `params.window` futures are outstanding; reports amortized
/// per-task time and overhead vs. the ideal grain.
pub fn run(rt: &Runtime, variant: Variant, params: &WorkloadParams) -> WorkloadReport {
    let injector = make_injector(params);
    // Ideal packed time per task across the pool, accounting for the n×
    // duplicated compute of replicate variants (the policy view keeps
    // this identical to the executor path's accounting).
    let multiplier = variant.compute_multiplier() as f64;
    let inj = injector.clone();
    run_windowed(rt, variant.label(), multiplier, params, &injector, move |rt| {
        launch(rt, variant, params.grain_ns, &inj)
    })
}

/// Executor-routed launches: the same workload, but every task goes
/// through a [`crate::resilience::executor`] decorator instead of a
/// resilient free-function call. The `table1_exec` harness measures this
/// path against the free functions. (Shared declarative spec — the
/// stencil driver's `--resilience` route uses the same type.)
pub use crate::resilience::executor::PolicySpec as ExecVariant;

/// Run the workload through an executor decorator (see [`ExecVariant`]).
pub fn run_executor(rt: &Runtime, variant: ExecVariant, params: &WorkloadParams) -> WorkloadReport {
    let exec = variant.build(rt, "workload", 2);
    let injector = make_injector(params);
    let inj = injector.clone();
    let grain_ns = params.grain_ns;
    run_windowed(
        rt,
        variant.label(),
        variant.compute_multiplier() as f64,
        params,
        &injector,
        move |_rt| {
            let inj = inj.clone();
            exec.spawn(move || universal_ans(grain_ns, &inj))
        },
    )
}

fn make_injector(params: &WorkloadParams) -> FaultInjector {
    match params.error_rate {
        Some(x) => FaultInjector::new(x, params.seed),
        None => FaultInjector::new(0.0, params.seed),
    }
}

/// The shared windowed measurement loop: launch `params.tasks` futures
/// through `launch_one`, keeping at most `params.window` outstanding, and
/// amortize the wall time into the report.
fn run_windowed<L>(
    rt: &Runtime,
    label: String,
    multiplier: f64,
    params: &WorkloadParams,
    injector: &FaultInjector,
    mut launch_one: L,
) -> WorkloadReport
where
    L: FnMut(&Runtime) -> Future<i32>,
{
    let mut launch_errors = 0u64;
    let timer = Timer::start();
    let mut inflight: std::collections::VecDeque<Future<i32>> =
        std::collections::VecDeque::with_capacity(params.window);
    for _ in 0..params.tasks {
        if inflight.len() >= params.window {
            let f = inflight.pop_front().expect("window non-empty");
            if f.get().is_err() {
                launch_errors += 1;
            }
        }
        inflight.push_back(launch_one(rt));
    }
    for f in inflight {
        if f.get().is_err() {
            launch_errors += 1;
        }
    }
    let wall = timer.elapsed_secs();

    let per_task_us = wall * 1e6 / params.tasks as f64;
    let grain_us = params.grain_ns as f64 / 1e3;
    let ideal_us = grain_us * multiplier / rt.workers() as f64;
    let overhead_us = per_task_us - ideal_us;
    let overhead_pct = 100.0 * overhead_us / grain_us;
    WorkloadReport {
        variant: label,
        tasks: params.tasks,
        wall_secs: wall,
        per_task_us,
        overhead_us,
        overhead_pct,
        failures_injected: injector.counters().injected(),
        launch_errors,
    }
}

/// Convenience used by benches: run every Table-I variant.
pub fn run_all_variants(rt: &Runtime, n: usize, params: &WorkloadParams) -> Vec<WorkloadReport> {
    Variant::table1_variants(n)
        .into_iter()
        .map(|v| run(rt, v, params))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn universal_ans_returns_42() {
        let inj = FaultInjector::new(0.0, 1);
        assert_eq!(universal_ans(1000, &inj), Ok(42));
    }

    #[test]
    fn universal_ans_fails_when_injected() {
        let inj = FaultInjector::with_probability(0.999_999, 2);
        let saw_failure = (0..50).any(|_| universal_ans(100, &inj).is_err());
        assert!(saw_failure);
    }

    #[test]
    fn plain_run_no_failures() {
        let rt = rt();
        let params = WorkloadParams { tasks: 200, grain_ns: 10_000, ..Default::default() };
        let rep = run(&rt, Variant::Plain, &params);
        assert_eq!(rep.tasks, 200);
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.failures_injected, 0);
        assert!(rep.wall_secs > 0.0);
    }

    #[test]
    fn replay_run_with_failures_all_recover() {
        let rt = rt();
        let params = WorkloadParams {
            tasks: 300,
            grain_ns: 5_000,
            error_rate: Some(1.0), // P(fail) ≈ 0.37 per attempt
            ..Default::default()
        };
        let rep = run(&rt, Variant::Replay { n: 10 }, &params);
        assert!(rep.failures_injected > 0, "injector must fire");
        assert_eq!(rep.launch_errors, 0, "replay(10) should always recover");
    }

    #[test]
    fn replicate_vote_run_recovers() {
        let rt = rt();
        let params = WorkloadParams {
            tasks: 100,
            grain_ns: 5_000,
            error_rate: Some(3.0), // P(fail) ≈ 0.05
            ..Default::default()
        };
        let rep = run(&rt, Variant::ReplicateVote { n: 3 }, &params);
        // All-three-replicas-fail has p ≈ 1.25e-4 per launch; over 100
        // launches failures are unlikely but not impossible — accept <= 1.
        assert!(rep.launch_errors <= 1, "got {}", rep.launch_errors);
    }

    #[test]
    fn executor_replay_run_with_failures_all_recover() {
        let rt = rt();
        let params = WorkloadParams {
            tasks: 300,
            grain_ns: 5_000,
            error_rate: Some(1.0), // P(fail) ≈ 0.37 per attempt
            ..Default::default()
        };
        let rep = run_executor(&rt, ExecVariant::Replay { n: 10 }, &params);
        assert!(rep.failures_injected > 0, "injector must fire");
        // P(10 consecutive fails) ≈ 0.37^10 per launch: a sub-percent
        // exhaustion tail exists over 300 launches, so tolerate <= 1.
        assert!(rep.launch_errors <= 1, "got {}", rep.launch_errors);
        assert_eq!(rep.variant, "exec_replay(10)");
    }

    #[test]
    fn executor_replicate_and_adaptive_run_clean() {
        let rt = rt();
        let params = WorkloadParams { tasks: 100, grain_ns: 5_000, ..Default::default() };
        let rep = run_executor(&rt, ExecVariant::Replicate { n: 3 }, &params);
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.variant, "exec_replicate(3)");
        let rep = run_executor(&rt, ExecVariant::Adaptive { ceiling: 6 }, &params);
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.variant, "exec_adaptive(max 6)");
    }

    #[test]
    fn executor_adaptive_replicate_recovers_under_failures() {
        let rt = rt();
        let params = WorkloadParams {
            tasks: 300,
            grain_ns: 5_000,
            error_rate: Some(4.0), // P(fail) ≈ 0.018 per replica
            ..Default::default()
        };
        let rep = run_executor(&rt, ExecVariant::AdaptiveReplicate { ceiling: 4 }, &params);
        assert_eq!(rep.variant, "exec_adaptive_replicate(max 4)");
        assert!(rep.failures_injected > 0, "injector must fire");
        // All launches may sample the quiet-state width (2) before any
        // outcome feeds back (the launch window far exceeds the task
        // count), so a launch fails iff both replicas fail: p ≈ 3.4e-4,
        // an expected 0.1 failures over 300 launches — tolerate a ≤2
        // tail (P ≈ 1.5e-4).
        assert!(rep.launch_errors <= 2, "got {}", rep.launch_errors);
    }

    #[test]
    fn variant_labels() {
        assert_eq!(Variant::Plain.label(), "async");
        assert_eq!(Variant::Replay { n: 3 }.label(), "async_replay(3)");
        assert_eq!(Variant::table1_variants(3).len(), 6);
        assert!(Variant::Replicate { n: 3 }.is_replicate());
        assert!(!Variant::Replay { n: 3 }.is_replicate());
    }

    #[test]
    fn variant_is_a_view_over_policy_spec() {
        use crate::resilience::executor::PolicySpec;
        assert_eq!(Variant::Plain.policy(), None);
        for v in Variant::table1_variants(3) {
            let p = v.policy().expect("every resilient variant has a base policy");
            match p {
                PolicySpec::Replay { n } => {
                    assert_eq!(n, 3);
                    assert!(!v.is_replicate());
                }
                PolicySpec::Replicate { n } => {
                    assert_eq!(n, 3);
                    assert!(v.is_replicate());
                }
                other => panic!("unexpected base policy {other:?}"),
            }
            // The view's multiplier is the policy's, never a private
            // re-derivation.
            assert_eq!(v.compute_multiplier(), p.compute_multiplier());
        }
        // The API dressing on top of the base policy.
        assert!(Variant::ReplayValidate { n: 2 }.validates());
        assert!(!Variant::Replay { n: 2 }.validates());
        assert!(Variant::ReplicateVote { n: 3 }.votes());
        assert!(Variant::ReplicateVoteValidate { n: 3 }.votes());
        assert!(Variant::ReplicateVoteValidate { n: 3 }.validates());
        assert!(!Variant::ReplicateValidate { n: 3 }.votes());
        assert_eq!(Variant::Plain.compute_multiplier(), 1);
    }
}
