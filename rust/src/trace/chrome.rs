//! `trace::chrome` — Chrome trace-event JSON export.
//!
//! Output is the [Trace Event Format] JSON object form: a
//! `traceEvents` array of `ph:"B"`/`ph:"E"` span pairs (task exec
//! spans), `ph:"i"` instants (everything else — faults render with
//! `cat:"fault"` so they stand out), and `ph:"M"` metadata naming each
//! process/thread lane. Load the file at `ui.perfetto.dev` or
//! `chrome://tracing`. Timestamps are microseconds since the trace
//! session epoch.
//!
//! Built on the crate's own [`JsonValue`] encoder, so every export
//! round-trips through [`JsonValue::parse`] — the property test in
//! `tests/properties.rs` pins that, plus B/E balance per lane.
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! ```
//! use rhpx::trace::{chrome, Event, EventKind, Track};
//!
//! let track = Track {
//!     pid: 1,
//!     tid: 1,
//!     name: "worker-0".into(),
//!     events: vec![
//!         Event { ts_ns: 1_000, kind: EventKind::ExecBegin, track: 0, a: 7, b: 0 },
//!         Event { ts_ns: 9_000, kind: EventKind::ExecEnd, track: 0, a: 7, b: 1 },
//!     ],
//! };
//! let json = chrome::chrome_trace(&[track], 0);
//! let text = json.render();
//! assert!(text.contains("\"ph\":\"B\"") && text.contains("\"ph\":\"E\""));
//! ```

use std::collections::BTreeSet;

use crate::metrics::JsonValue;

use super::{take_tracks, Event, EventKind, Track, WORKER_PID_BASE};

/// What an export produced (printed by `rhpx run --trace`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExportSummary {
    /// Exportable tracks (threads + remote locality lanes).
    pub tracks: usize,
    /// Matched exec spans (one B + one E each).
    pub spans: usize,
    /// Instant events (faults, lifecycle marks, unmatched span halves).
    pub instants: usize,
    /// Events lost to ring overwrite before export (session-cumulative,
    /// local + remote). Reported, never silent.
    pub dropped: u64,
}

fn us(ts_ns: u64) -> JsonValue {
    JsonValue::Num(ts_ns as f64 / 1000.0)
}

fn base(name: &str, ph: &str, ts_ns: u64, track: &Track) -> Vec<(String, JsonValue)> {
    vec![
        ("name".to_string(), JsonValue::from(name)),
        ("ph".to_string(), JsonValue::from(ph)),
        ("ts".to_string(), us(ts_ns)),
        ("pid".to_string(), JsonValue::from(track.pid as u64)),
        ("tid".to_string(), JsonValue::from(track.tid as u64)),
    ]
}

fn instant(e: &Event, track: &Track, name: &str) -> JsonValue {
    let mut fields = base(name, "i", e.ts_ns, track);
    fields.push(("s".to_string(), JsonValue::from("t")));
    fields.push((
        "cat".to_string(),
        JsonValue::from(if e.kind.is_fault() { "fault" } else { "task" }),
    ));
    fields.push((
        "args".to_string(),
        JsonValue::obj([
            ("a".to_string(), JsonValue::from(e.a)),
            ("b".to_string(), JsonValue::from(e.b)),
        ]),
    ));
    JsonValue::obj(fields)
}

fn metadata(name: &str, pid: u32, tid: Option<u32>, value: &str) -> JsonValue {
    let mut fields = vec![
        ("name".to_string(), JsonValue::from(name)),
        ("ph".to_string(), JsonValue::from("M")),
        ("pid".to_string(), JsonValue::from(pid as u64)),
        (
            "args".to_string(),
            JsonValue::obj([("name".to_string(), JsonValue::from(value))]),
        ),
    ];
    if let Some(tid) = tid {
        fields.push(("tid".to_string(), JsonValue::from(tid as u64)));
    }
    JsonValue::obj(fields)
}

/// Per-event export role, decided by the span-matching pass.
enum Role {
    /// Matched `ExecBegin` — emit `ph:"B"`.
    Begin,
    /// Matched `ExecEnd` — emit `ph:"E"`.
    End,
    /// Everything else (including unmatched span halves) — `ph:"i"`.
    Instant(&'static str),
}

/// Match `ExecBegin`/`ExecEnd` pairs within one track. Events arrive in
/// time order and task execution on one thread nests (LIFO), so a stack
/// suffices; an end that does not match the innermost open begin — or a
/// begin the trace never saw closed (the killed-worker case) — degrades
/// to an instant rather than corrupting the viewer's span stack.
fn classify(events: &[Event]) -> Vec<Role> {
    let mut roles: Vec<Role> = events
        .iter()
        .map(|e| Role::Instant(e.kind.name()))
        .collect();
    let mut stack: Vec<usize> = Vec::new();
    for (i, e) in events.iter().enumerate() {
        match e.kind {
            EventKind::ExecBegin => stack.push(i),
            EventKind::ExecEnd => match stack.last() {
                Some(&top) if events[top].a == e.a => {
                    stack.pop();
                    roles[top] = Role::Begin;
                    roles[i] = Role::End;
                }
                _ => roles[i] = Role::Instant("exec_end_orphan"),
            },
            _ => {}
        }
    }
    for &i in &stack {
        roles[i] = Role::Instant("exec_unfinished");
    }
    roles
}

fn build(tracks: &[Track], dropped: u64) -> (JsonValue, ExportSummary) {
    let mut out: Vec<JsonValue> = Vec::new();
    let mut summary = ExportSummary { tracks: tracks.len(), dropped, ..Default::default() };

    let pids: BTreeSet<u32> = tracks.iter().map(|t| t.pid).collect();
    for pid in pids {
        let pname = if pid < WORKER_PID_BASE {
            "rhpx".to_string()
        } else {
            format!("locality {}", pid - WORKER_PID_BASE)
        };
        out.push(metadata("process_name", pid, None, &pname));
    }
    for t in tracks {
        out.push(metadata("thread_name", t.pid, Some(t.tid), &t.name));
    }

    for t in tracks {
        let roles = classify(&t.events);
        for (e, role) in t.events.iter().zip(&roles) {
            match role {
                Role::Begin => {
                    let mut fields = base("exec", "B", e.ts_ns, t);
                    fields.push(("cat".to_string(), JsonValue::from("task")));
                    fields.push((
                        "args".to_string(),
                        JsonValue::obj([("task".to_string(), JsonValue::from(e.a))]),
                    ));
                    out.push(JsonValue::obj(fields));
                    summary.spans += 1;
                }
                Role::End => {
                    let mut fields = base("exec", "E", e.ts_ns, t);
                    fields.push(("cat".to_string(), JsonValue::from("task")));
                    out.push(JsonValue::obj(fields));
                }
                Role::Instant(name) => {
                    out.push(instant(e, t, name));
                    summary.instants += 1;
                }
            }
        }
    }

    let json = JsonValue::obj([
        ("traceEvents".to_string(), JsonValue::Arr(out)),
        ("displayTimeUnit".to_string(), JsonValue::from("ms")),
        (
            "otherData".to_string(),
            JsonValue::obj([
                ("dropped_events".to_string(), JsonValue::from(dropped)),
                ("tracks".to_string(), JsonValue::from(tracks.len())),
            ]),
        ),
    ]);
    (json, summary)
}

/// Render `tracks` as a Chrome trace-event JSON document. Pure — the
/// `rhpx trace convert` subcommand and the tests feed it spool-derived
/// tracks without ever touching the global session.
pub fn chrome_trace(tracks: &[Track], dropped: u64) -> JsonValue {
    build(tracks, dropped).0
}

/// Drain the global session ([`take_tracks`]) and write it to `path` as
/// Chrome trace-event JSON.
pub fn export(path: &str) -> std::io::Result<ExportSummary> {
    let (tracks, dropped) = take_tracks();
    export_tracks(path, &tracks, dropped)
}

/// Write pre-assembled tracks to `path` (the convert path).
pub fn export_tracks(
    path: &str,
    tracks: &[Track],
    dropped: u64,
) -> std::io::Result<ExportSummary> {
    let (json, summary) = build(tracks, dropped);
    std::fs::write(path, json.render() + "\n")?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts_ns: u64, kind: EventKind, a: u64, b: u64) -> Event {
        Event { ts_ns, kind, track: 0, a, b }
    }

    fn phases(json: &JsonValue) -> Vec<(String, String)> {
        json.get("traceEvents")
            .and_then(JsonValue::as_arr)
            .unwrap()
            .iter()
            .map(|e| {
                (
                    e.get("ph").and_then(JsonValue::as_str).unwrap().to_string(),
                    e.get("name").and_then(JsonValue::as_str).unwrap().to_string(),
                )
            })
            .collect()
    }

    #[test]
    fn spans_pair_and_faults_are_instants() {
        let track = Track {
            pid: 1,
            tid: 1,
            name: "worker-0".into(),
            events: vec![
                ev(1_000, EventKind::Spawn, 7, 0),
                ev(2_000, EventKind::ExecBegin, 7, 0),
                ev(3_000, EventKind::ValidateFail, 7, 0),
                ev(4_000, EventKind::ExecEnd, 7, 1),
            ],
        };
        let (json, summary) = build(&[track], 5);
        assert_eq!(summary.spans, 1);
        assert_eq!(summary.instants, 2);
        assert_eq!(summary.dropped, 5);
        let ph = phases(&json);
        let b = ph.iter().filter(|(p, _)| p == "B").count();
        let e = ph.iter().filter(|(p, _)| p == "E").count();
        assert_eq!((b, e), (1, 1));
        let text = json.render();
        assert!(text.contains("\"cat\":\"fault\""), "{text}");
        assert!(text.contains("\"dropped_events\":5"), "{text}");
        // ts is microseconds: 1_000 ns = 1 µs (keys render sorted, so
        // "ts" is the last field of its object).
        assert!(text.contains("\"ts\":1}"), "{text}");
        // Round-trips through the crate's own parser.
        assert_eq!(JsonValue::parse(&text).unwrap(), json);
    }

    #[test]
    fn nested_spans_stay_nested() {
        let track = Track {
            pid: 1,
            tid: 2,
            name: "w".into(),
            events: vec![
                ev(10, EventKind::ExecBegin, 1, 0),
                ev(20, EventKind::ExecBegin, 2, 0),
                ev(30, EventKind::ExecEnd, 2, 1),
                ev(40, EventKind::ExecEnd, 1, 1),
            ],
        };
        let (json, summary) = build(&[track], 0);
        assert_eq!(summary.spans, 2);
        // B B E E in event order: the viewer's span stack never breaks.
        let seq: Vec<String> = phases(&json)
            .into_iter()
            .filter(|(p, _)| p == "B" || p == "E")
            .map(|(p, _)| p)
            .collect();
        assert_eq!(seq, vec!["B", "B", "E", "E"]);
    }

    #[test]
    fn unmatched_halves_degrade_to_instants() {
        // A killed worker's last ExecBegin never sees its end; a replayed
        // stream may carry an orphan end after a wraparound.
        let track = Track {
            pid: 3,
            tid: 1,
            name: "loc1/t0".into(),
            events: vec![
                ev(10, EventKind::ExecEnd, 9, 1),
                ev(20, EventKind::ExecBegin, 5, 0),
            ],
        };
        let (json, summary) = build(&[track], 0);
        assert_eq!(summary.spans, 0);
        assert_eq!(summary.instants, 2);
        let names: Vec<String> = phases(&json)
            .into_iter()
            .filter(|(p, _)| p == "i")
            .map(|(_, n)| n)
            .collect();
        assert!(names.contains(&"exec_end_orphan".to_string()), "{names:?}");
        assert!(names.contains(&"exec_unfinished".to_string()), "{names:?}");
        // No B without E anywhere in the document.
        assert!(phases(&json).iter().all(|(p, _)| p != "B" && p != "E"));
    }

    #[test]
    fn metadata_names_every_lane() {
        let tracks = vec![
            Track { pid: 1, tid: 1, name: "main".into(), events: vec![] },
            Track { pid: 4, tid: 1, name: "loc2/t0".into(), events: vec![] },
        ];
        let (json, _) = build(&tracks, 0);
        let text = json.render();
        assert!(text.contains("\"process_name\""), "{text}");
        assert!(text.contains("locality 2"), "{text}");
        assert!(text.contains("\"thread_name\""), "{text}");
        assert!(text.contains("\"main\""), "{text}");
    }

    #[test]
    fn export_tracks_writes_parseable_json() {
        let path = std::env::temp_dir()
            .join(format!("rhpx_chrome_{}.trace.json", std::process::id()));
        let track = Track {
            pid: 1,
            tid: 1,
            name: "t".into(),
            events: vec![ev(1, EventKind::Spawn, 1, 0)],
        };
        let summary =
            export_tracks(path.to_str().unwrap(), &[track], 2).expect("write");
        assert_eq!(summary.instants, 1);
        assert_eq!(summary.dropped, 2);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(JsonValue::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}
