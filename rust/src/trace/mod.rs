//! `trace` — a per-worker, lock-free flight recorder for task-lifecycle
//! events, with Chrome-trace export ([`chrome`]) and a crash-surviving
//! binary spool for process-backed localities ([`spool`]).
//!
//! The paper's headline claim is an *attribution* claim — most of the
//! resilience overhead comes from replayed/replicated task bodies, not
//! the APIs — and aggregate counters cannot show attribution. This
//! module records *where time went*: every spawn, steal, exec span,
//! replay attempt, replica race, checkpoint save/restore, validation
//! verdict, heartbeat miss, and death verdict, stamped with monotonic
//! nanoseconds and a per-thread track id.
//!
//! Design (the ORNL resilience-patterns "monitoring" structural pattern,
//! arXiv 1611.02717, applied to the runtime itself):
//!
//! - **One [`Ring`] per recording thread** — fixed capacity, overwrite-
//!   oldest, a single atomic write cursor. The record path performs no
//!   allocation and takes no lock: five atomic stores into a seqlock-
//!   stamped slot. Readers ([`Ring::drain`]) run concurrently on any
//!   thread; a slot overwritten mid-read is *counted as dropped*, never
//!   silently lost or torn.
//! - **A process-global session** gated by one static `AtomicBool`:
//!   when tracing is off, [`emit`] is a single relaxed load and a
//!   branch — effectively a no-op compiled into the seams. Threads
//!   register lazily on first emit and get a named track.
//! - **Two sinks.** [`chrome::export`] writes Chrome trace-event JSON
//!   (load it at `ui.perfetto.dev` or `chrome://tracing`);
//!   [`spool::SpoolWriter`] appends framed [`spool::TraceChunk`]s to an
//!   fsynced file *and* streams the same chunks to the parent process,
//!   so a `kill -9`'d worker's final flushed events survive for
//!   post-mortem stitching ([`spool::merge_chunks`]).
//!
//! ```
//! use rhpx::trace::{EventKind, Ring};
//!
//! let ring = Ring::new(8, 0);
//! ring.record(10, EventKind::Spawn, 1, 0);
//! ring.record(20, EventKind::ExecBegin, 1, 0);
//! ring.record(30, EventKind::ExecEnd, 1, 0);
//! let d = ring.drain();
//! assert_eq!(d.dropped, 0);
//! assert_eq!(d.events.len(), 3);
//! assert_eq!(d.events[0].kind, EventKind::Spawn);
//! ```

pub mod chrome;
pub mod spool;

use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity (events), rounded up to a power of
/// two. At ~3 events per task this holds the last ~5k tasks per worker.
pub const DEFAULT_CAPACITY: usize = 1 << 14;

/// Chrome-trace `pid` of the driving (parent) process.
pub const PARENT_PID: u32 = 1;

/// Chrome-trace `pid` base for worker localities: locality `L` renders
/// as pid `WORKER_PID_BASE + L`.
pub const WORKER_PID_BASE: u32 = 2;

/// Typed task-lifecycle event kinds. Discriminants are the wire
/// encoding ([`spool`]) — append-only; never renumber.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// Task handed to the scheduler. `a` = spawn sequence number.
    Spawn = 1,
    /// A worker stole a task. `a` = thief index, `b` = victim index.
    Steal = 2,
    /// Task body starts on this track. `a` = task/launch id (0 for
    /// anonymous pool jobs). Paired with [`EventKind::ExecEnd`].
    ExecBegin = 3,
    /// Task body finished. `a` = task/launch id, `b` = 1 if it returned
    /// Ok.
    ExecEnd = 4,
    /// Replay retry `b` (1-based) of launch token `a`.
    ReplayAttempt = 5,
    /// Replica `b` of launch token `a` submitted.
    ReplicaLaunch = 6,
    /// A replica's result was accepted for token `a`.
    ReplicaWin = 7,
    /// A losing team replica observed cancellation (token `a`).
    ReplicaCancel = 8,
    /// Checkpoint stored. `a` = FNV hash of the key, `b` = bytes.
    CheckpointSave = 9,
    /// Checkpoint hit. `a` = FNV hash of the key, `b` = bytes.
    CheckpointRestore = 10,
    /// Snapshots re-homed off dead locality `a`.
    CheckpointRehome = 11,
    /// Validator accepted a result (launch token `a`).
    ValidatePass = 12,
    /// Validator rejected a result (launch token `a`).
    ValidateFail = 13,
    /// Injected silent-data-corruption bit-flip actually landed.
    SdcFlip = 14,
    /// Service admission rejected job `a` (`b`: 0 = queue, 1 = breaker).
    AdmissionReject = 15,
    /// Circuit-breaker observation for class hash `a` (`b`: 0 = open
    /// rejected a request, 1 = half-open probe admitted).
    BreakerTransition = 16,
    /// Locality `a` has missed `b` consecutive heartbeat periods.
    HeartbeatMiss = 17,
    /// The monitor declared locality `a` dead.
    DeathVerdict = 18,
    /// In-flight call `b`, homed on dead locality `a`, drained.
    Drain = 19,
    /// Call `a` (lost on locality `b`) re-materialized on a survivor.
    Rematerialize = 20,
}

impl EventKind {
    /// Every kind, in discriminant order (the taxonomy table in
    /// ARCHITECTURE.md mirrors this).
    pub const ALL: [EventKind; 20] = [
        EventKind::Spawn,
        EventKind::Steal,
        EventKind::ExecBegin,
        EventKind::ExecEnd,
        EventKind::ReplayAttempt,
        EventKind::ReplicaLaunch,
        EventKind::ReplicaWin,
        EventKind::ReplicaCancel,
        EventKind::CheckpointSave,
        EventKind::CheckpointRestore,
        EventKind::CheckpointRehome,
        EventKind::ValidatePass,
        EventKind::ValidateFail,
        EventKind::SdcFlip,
        EventKind::AdmissionReject,
        EventKind::BreakerTransition,
        EventKind::HeartbeatMiss,
        EventKind::DeathVerdict,
        EventKind::Drain,
        EventKind::Rematerialize,
    ];

    /// Decode a wire discriminant.
    pub fn from_u8(b: u8) -> Option<EventKind> {
        EventKind::ALL.get(b.wrapping_sub(1) as usize).copied()
    }

    /// Stable display name (the Chrome-trace event name).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Spawn => "spawn",
            EventKind::Steal => "steal",
            EventKind::ExecBegin => "exec_begin",
            EventKind::ExecEnd => "exec_end",
            EventKind::ReplayAttempt => "replay_attempt",
            EventKind::ReplicaLaunch => "replica_launch",
            EventKind::ReplicaWin => "replica_win",
            EventKind::ReplicaCancel => "replica_cancel",
            EventKind::CheckpointSave => "checkpoint_save",
            EventKind::CheckpointRestore => "checkpoint_restore",
            EventKind::CheckpointRehome => "checkpoint_rehome",
            EventKind::ValidatePass => "validate_pass",
            EventKind::ValidateFail => "validate_fail",
            EventKind::SdcFlip => "sdc_flip",
            EventKind::AdmissionReject => "admission_reject",
            EventKind::BreakerTransition => "breaker_transition",
            EventKind::HeartbeatMiss => "heartbeat_miss",
            EventKind::DeathVerdict => "death_verdict",
            EventKind::Drain => "drain",
            EventKind::Rematerialize => "rematerialize",
        }
    }

    /// Fault-ish kinds render as highlighted instants in the export.
    pub fn is_fault(self) -> bool {
        matches!(
            self,
            EventKind::ValidateFail
                | EventKind::SdcFlip
                | EventKind::AdmissionReject
                | EventKind::BreakerTransition
                | EventKind::HeartbeatMiss
                | EventKind::DeathVerdict
                | EventKind::Drain
        )
    }
}

/// One recorded event: monotonic nanoseconds since the session start,
/// the kind, the recording track, and two kind-specific operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub ts_ns: u64,
    pub kind: EventKind,
    pub track: u32,
    pub a: u64,
    pub b: u64,
}

/// FNV-1a over a string key — the stable 64-bit handle events carry for
/// string-typed operands (checkpoint keys, breaker classes).
pub fn key_hash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One seqlock-stamped slot. Fields are individually atomic (no torn
/// word is possible); `seq` guards cross-field consistency: odd while a
/// write is in flight, `2 * (index + 1)` once generation `index` is
/// stable. `seq == 0` means never written.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    ts: AtomicU64,
    kind_track: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

/// Result of [`Ring::drain`]: the consistent events read, plus how many
/// were lost to overwrite (or to a writer racing the read) since the
/// previous drain. Dropped events are *counted*, never silent.
#[derive(Debug, Default)]
pub struct Drained {
    pub events: Vec<Event>,
    pub dropped: u64,
}

/// Fixed-capacity, overwrite-oldest event ring with one atomic write
/// cursor. Single producer (the owning thread), any-thread reader.
///
/// The record path is five atomic stores and one cursor store — no
/// allocation, no lock, no CAS loop. Overwrite never blocks on the
/// reader: a reader that loses the race to an overwriting writer
/// discards the torn slot and counts it dropped.
pub struct Ring {
    slots: Box<[Slot]>,
    /// Total events ever recorded (monotonic write cursor).
    cursor: AtomicU64,
    /// Low-water mark of [`Ring::drain`] (events consumed).
    read_cursor: AtomicU64,
    /// Events overwritten or torn before a drain could read them.
    dropped: AtomicU64,
    track: u32,
}

impl Ring {
    /// A ring holding the last `capacity` events (rounded up to a power
    /// of two, minimum 2) for track `track`.
    pub fn new(capacity: usize, track: u32) -> Ring {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Vec<Slot> = (0..cap).map(|_| Slot::default()).collect();
        Ring {
            slots: slots.into_boxed_slice(),
            cursor: AtomicU64::new(0),
            read_cursor: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            track,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn track(&self) -> u32 {
        self.track
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.cursor.load(Ordering::Acquire)
    }

    /// Cumulative events lost to overwrite/tearing across all drains.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record one event. Single-producer: only the owning thread calls
    /// this. Seqlock write protocol: mark the slot odd, publish the
    /// fields, mark it even with the new generation.
    #[inline]
    pub fn record(&self, ts_ns: u64, kind: EventKind, a: u64, b: u64) {
        let i = self.cursor.load(Ordering::Relaxed);
        let slot = &self.slots[(i as usize) & (self.slots.len() - 1)];
        slot.seq.store(2 * i + 1, Ordering::Relaxed);
        // The odd mark must hit memory before any field does, or a
        // racing reader could mix generations without noticing.
        fence(Ordering::Release);
        slot.ts.store(ts_ns, Ordering::Relaxed);
        slot.kind_track.store(kind as u64 | ((self.track as u64) << 32), Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(2 * (i + 1), Ordering::Release);
        self.cursor.store(i + 1, Ordering::Release);
    }

    /// Seqlock read of logical index `i`; `None` if the slot no longer
    /// (or not yet consistently) holds generation `i`.
    fn read_at(&self, i: u64) -> Option<Event> {
        let slot = &self.slots[(i as usize) & (self.slots.len() - 1)];
        let s1 = slot.seq.load(Ordering::Acquire);
        let ts = slot.ts.load(Ordering::Relaxed);
        let kind_track = slot.kind_track.load(Ordering::Relaxed);
        let a = slot.a.load(Ordering::Relaxed);
        let b = slot.b.load(Ordering::Relaxed);
        fence(Ordering::Acquire);
        let s2 = slot.seq.load(Ordering::Relaxed);
        if s1 != s2 || s1 % 2 == 1 || s1 != 2 * (i + 1) {
            return None; // torn, in-flight, or already overwritten
        }
        let kind = EventKind::from_u8((kind_track & 0xFF) as u8)?;
        Some(Event { ts_ns: ts, kind, track: (kind_track >> 32) as u32, a, b })
    }

    /// Read and consume everything recorded since the previous drain
    /// (oldest first). Events overwritten before this drain reached
    /// them — and slots torn by a writer racing the read — are counted
    /// in [`Drained::dropped`] and in the cumulative [`Ring::dropped`].
    pub fn drain(&self) -> Drained {
        let cur = self.cursor.load(Ordering::Acquire);
        let next = self.read_cursor.load(Ordering::Relaxed);
        let lo = cur.saturating_sub(self.slots.len() as u64).max(next);
        let overwritten = lo - next;
        let mut events = Vec::with_capacity((cur - lo) as usize);
        let mut torn = 0u64;
        for i in lo..cur {
            match self.read_at(i) {
                Some(e) => events.push(e),
                None => torn += 1,
            }
        }
        // The writer may have advanced while we scanned; anything it
        // wrote past `cur` stays for the next drain.
        self.read_cursor.store(cur, Ordering::Relaxed);
        let dropped = overwritten + torn;
        if dropped > 0 {
            self.dropped.fetch_add(dropped, Ordering::Relaxed);
        }
        Drained { events, dropped }
    }
}

/// A per-thread handle into the session's ring. Cloning is cheap (an
/// `Arc` bump); [`Recorder::off`] is the no-op handle used when tracing
/// is disabled — its [`Recorder::emit`] compiles down to a null check.
#[derive(Clone)]
pub struct Recorder {
    ring: Option<Arc<Ring>>,
}

impl Recorder {
    /// The no-op recorder (tracing off).
    pub fn off() -> Recorder {
        Recorder { ring: None }
    }

    pub fn is_on(&self) -> bool {
        self.ring.is_some()
    }

    /// Record an event on this thread's track (no-op when off).
    #[inline]
    pub fn emit(&self, kind: EventKind, a: u64, b: u64) {
        if let Some(ring) = &self.ring {
            ring.record(now_ns(), kind, a, b);
        }
    }
}

/// One exportable track: a Chrome-trace (pid, tid) lane plus its name
/// and time-ordered events.
#[derive(Debug, Clone)]
pub struct Track {
    pub pid: u32,
    pub tid: u32,
    pub name: String,
    pub events: Vec<Event>,
}

struct Session {
    start: Instant,
    rings: Mutex<Vec<Arc<Ring>>>,
    names: Mutex<Vec<String>>,
    remote: Mutex<Vec<(u32, Vec<Event>)>>,
    remote_dropped: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn session() -> &'static Session {
    static S: OnceLock<Session> = OnceLock::new();
    S.get_or_init(|| Session {
        start: Instant::now(),
        rings: Mutex::new(Vec::new()),
        names: Mutex::new(Vec::new()),
        remote: Mutex::new(Vec::new()),
        remote_dropped: AtomicU64::new(0),
    })
}

/// Monotonic nanoseconds since the session epoch.
pub fn now_ns() -> u64 {
    session().start.elapsed().as_nanos() as u64
}

/// Turn the global recorder on. Threads register their track lazily on
/// first [`emit`]. Idempotent.
pub fn enable() {
    session();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn the global recorder off: [`emit`] returns to its one-load
/// fast path. Already-recorded events stay drainable.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Is the global recorder on?
#[inline]
pub fn active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

thread_local! {
    static RECORDER: std::cell::RefCell<Option<Recorder>> =
        const { std::cell::RefCell::new(None) };
}

/// This thread's recorder handle: registers a named track on first use
/// while tracing is on; [`Recorder::off`] while tracing is off.
pub fn recorder() -> Recorder {
    if !active() {
        return Recorder::off();
    }
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        if r.is_none() {
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_default();
            *r = Some(register_track(name));
        }
        r.as_ref().expect("registered above").clone()
    })
}

fn register_track(name: String) -> Recorder {
    let s = session();
    let mut rings = s.rings.lock().unwrap();
    let track = rings.len() as u32;
    let ring = Arc::new(Ring::new(DEFAULT_CAPACITY, track));
    rings.push(Arc::clone(&ring));
    let name = if name.is_empty() { format!("thread-{track}") } else { name };
    s.names.lock().unwrap().push(name);
    Recorder { ring: Some(ring) }
}

/// Record one event on the calling thread's track. This is the call the
/// runtime seams compile in: when tracing is off it is one relaxed
/// atomic load and a branch.
#[inline]
pub fn emit(kind: EventKind, a: u64, b: u64) {
    if !active() {
        return;
    }
    recorder().emit(kind, a, b);
}

/// Fold events shipped from a worker process (locality `locality`) into
/// the session, with that process's own dropped count.
pub fn ingest_remote(locality: u32, events: Vec<Event>, dropped: u64) {
    let s = session();
    if dropped > 0 {
        s.remote_dropped.fetch_add(dropped, Ordering::Relaxed);
    }
    if !events.is_empty() {
        s.remote.lock().unwrap().push((locality, events));
    }
}

/// Session totals: `(events recorded on local tracks, events dropped —
/// local rings + remote chunks)`.
pub fn totals() -> (u64, u64) {
    let s = session();
    let rings: Vec<Arc<Ring>> = s.rings.lock().unwrap().clone();
    let recorded = rings.iter().map(|r| r.total()).sum();
    let dropped = rings.iter().map(|r| r.dropped()).sum::<u64>()
        + s.remote_dropped.load(Ordering::Relaxed);
    (recorded, dropped)
}

/// Drain every local ring (for the spool flusher in worker processes).
/// Returns all undrained events across tracks plus the incremental
/// dropped count.
pub fn drain_all() -> Drained {
    let s = session();
    let rings: Vec<Arc<Ring>> = s.rings.lock().unwrap().clone();
    let mut out = Drained::default();
    for ring in rings {
        let d = ring.drain();
        out.events.extend(d.events);
        out.dropped += d.dropped;
    }
    out
}

/// Drain the session into exportable tracks: one per local thread, one
/// per (locality, remote track) of ingested worker events. Returns the
/// tracks and the *cumulative* session dropped count.
pub fn take_tracks() -> (Vec<Track>, u64) {
    let s = session();
    let rings: Vec<Arc<Ring>> = s.rings.lock().unwrap().clone();
    let names: Vec<String> = s.names.lock().unwrap().clone();
    let mut tracks = Vec::new();
    for ring in &rings {
        let d = ring.drain();
        let name = names
            .get(ring.track() as usize)
            .cloned()
            .unwrap_or_else(|| format!("thread-{}", ring.track()));
        tracks.push(Track { pid: PARENT_PID, tid: ring.track() + 1, name, events: d.events });
    }
    let remote: Vec<(u32, Vec<Event>)> = std::mem::take(&mut *s.remote.lock().unwrap());
    let mut by: std::collections::BTreeMap<(u32, u32), Vec<Event>> = Default::default();
    for (loc, events) in remote {
        for e in events {
            by.entry((loc, e.track)).or_default().push(e);
        }
    }
    for ((loc, track), mut events) in by {
        events.sort_by_key(|e| e.ts_ns);
        tracks.push(Track {
            pid: WORKER_PID_BASE + loc,
            tid: track + 1,
            name: format!("loc{loc}/t{track}"),
            events,
        });
    }
    let dropped = rings.iter().map(|r| r.dropped()).sum::<u64>()
        + s.remote_dropped.load(Ordering::Relaxed);
    (tracks, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_discriminants_roundtrip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_u8(k as u8), Some(k), "{k:?}");
            assert!(!k.name().is_empty());
        }
        assert_eq!(EventKind::from_u8(0), None);
        assert_eq!(EventKind::from_u8(21), None);
        assert_eq!(EventKind::from_u8(255), None);
    }

    #[test]
    fn ring_records_in_order() {
        let ring = Ring::new(8, 3);
        for i in 0..5u64 {
            ring.record(100 + i, EventKind::Spawn, i, i * 2);
        }
        let d = ring.drain();
        assert_eq!(d.dropped, 0);
        assert_eq!(d.events.len(), 5);
        for (i, e) in d.events.iter().enumerate() {
            assert_eq!(e.ts_ns, 100 + i as u64);
            assert_eq!(e.a, i as u64);
            assert_eq!(e.track, 3);
        }
        // A second drain sees only what arrived since.
        assert!(ring.drain().events.is_empty());
        ring.record(999, EventKind::Steal, 7, 8);
        let d = ring.drain();
        assert_eq!(d.events.len(), 1);
        assert_eq!(d.events[0].kind, EventKind::Steal);
    }

    #[test]
    fn wraparound_overwrites_oldest_and_counts_drops() {
        let ring = Ring::new(4, 0);
        for i in 0..10u64 {
            ring.record(i, EventKind::Spawn, i, 0);
        }
        let d = ring.drain();
        // Capacity 4: the last 4 events survive, 6 were overwritten.
        assert_eq!(d.events.len(), 4);
        assert_eq!(d.dropped, 6);
        assert_eq!(ring.dropped(), 6);
        assert_eq!(ring.total(), 10);
        let kept: Vec<u64> = d.events.iter().map(|e| e.a).collect();
        assert_eq!(kept, vec![6, 7, 8, 9]);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(Ring::new(0, 0).capacity(), 2);
        assert_eq!(Ring::new(5, 0).capacity(), 8);
        assert_eq!(Ring::new(8, 0).capacity(), 8);
    }

    #[test]
    fn off_recorder_is_a_noop() {
        let r = Recorder::off();
        assert!(!r.is_on());
        r.emit(EventKind::Spawn, 1, 2); // must not panic or record
    }

    #[test]
    fn key_hash_is_stable_fnv() {
        assert_eq!(key_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(key_hash("a"), key_hash("b"));
        assert_eq!(key_hash("ckpt_4_1"), key_hash("ckpt_4_1"));
    }

    // The single test in this binary that touches the global session
    // (everything else drives `Ring`s directly so parallel test threads
    // never fight over the ENABLED flag).
    #[test]
    fn global_session_registers_tracks_and_exports() {
        enable();
        assert!(active());
        emit(EventKind::Spawn, 41, 0);
        emit(EventKind::ExecBegin, 41, 0);
        emit(EventKind::ExecEnd, 41, 1);
        ingest_remote(
            2,
            vec![Event { ts_ns: 5, kind: EventKind::DeathVerdict, track: 0, a: 2, b: 0 }],
            3,
        );
        let (tracks, dropped) = take_tracks();
        assert!(dropped >= 3, "remote dropped count folds in: {dropped}");
        let mine = tracks
            .iter()
            .find(|t| t.pid == PARENT_PID && t.events.iter().any(|e| e.a == 41))
            .expect("this thread's track");
        assert_eq!(mine.events.iter().filter(|e| e.a == 41).count(), 3);
        let remote = tracks
            .iter()
            .find(|t| t.pid == WORKER_PID_BASE + 2)
            .expect("remote track");
        assert_eq!(remote.name, "loc2/t0");
        assert_eq!(remote.events[0].kind, EventKind::DeathVerdict);
        disable();
        assert!(!active());
        emit(EventKind::Spawn, 999, 0); // no-op while off
        let (tracks, _) = take_tracks();
        assert!(
            tracks.iter().all(|t| t.events.iter().all(|e| e.a != 999)),
            "emit after disable recorded"
        );
    }
}
