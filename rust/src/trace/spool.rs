//! `trace::spool` — the crash-surviving binary trace sink for
//! process-backed localities.
//!
//! Worker processes drain their rings into [`TraceChunk`]s and write
//! them twice: appended to a local spool file and fsynced
//! ([`SpoolWriter::append`]), *and* streamed to the parent as
//! [`Frame::Trace`] frames over the existing worker connection. After a
//! literal `kill -9` the parent stitches the two sources
//! ([`merge_chunks`] dedups by `(locality, seq)`), so the corpse's last
//! fsynced events make it into the merged timeline even though its
//! socket died mid-stream — post-mortem forensics the simulated cluster
//! never needed.
//!
//! A spool file is nothing but concatenated encoded frames (the PR 8
//! framing: magic, version, tag, length, FNV-1a trailer). A process
//! killed mid-append leaves a truncated final frame; [`read_spool_file`]
//! keeps the valid prefix and drops the torn tail — the same
//! "total decode" discipline as the wire.

use std::path::{Path, PathBuf};

use crate::checkpoint::SnapshotData;
use crate::serve::protocol::Frame;

use super::{Event, EventKind, Track, WORKER_PID_BASE};

/// Events per chunk cap: keeps every encoded frame well under the
/// protocol's payload cap (29 bytes/event ⇒ ~240 KiB per chunk).
pub const MAX_EVENTS_PER_CHUNK: usize = 8192;

const EVENT_WIRE_BYTES: usize = 29;

/// One framed batch of trace events from one locality. `seq` is
/// per-locality and monotonic — the dedup key when the streamed and
/// spooled copies of the same chunk both survive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceChunk {
    pub locality: u32,
    pub seq: u64,
    /// Ring-dropped count the producer observed with this batch.
    pub dropped: u64,
    pub events: Vec<Event>,
}

impl SnapshotData for TraceChunk {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(24 + self.events.len() * EVENT_WIRE_BYTES);
        out.extend_from_slice(&self.locality.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.dropped.to_le_bytes());
        out.extend_from_slice(&(self.events.len() as u32).to_le_bytes());
        for e in &self.events {
            out.extend_from_slice(&e.ts_ns.to_le_bytes());
            out.extend_from_slice(&e.a.to_le_bytes());
            out.extend_from_slice(&e.b.to_le_bytes());
            out.extend_from_slice(&e.track.to_le_bytes());
            out.push(e.kind as u8);
        }
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        if bytes.len() < 24 {
            return None;
        }
        let locality = u32::from_le_bytes(bytes[0..4].try_into().ok()?);
        let seq = u64::from_le_bytes(bytes[4..12].try_into().ok()?);
        let dropped = u64::from_le_bytes(bytes[12..20].try_into().ok()?);
        let n = u32::from_le_bytes(bytes[20..24].try_into().ok()?) as usize;
        let body = &bytes[24..];
        // The count is untrusted: it must exactly cover the bytes present.
        if body.len() != n.checked_mul(EVENT_WIRE_BYTES)? {
            return None;
        }
        let mut events = Vec::with_capacity(n);
        for chunk in body.chunks_exact(EVENT_WIRE_BYTES) {
            events.push(Event {
                ts_ns: u64::from_le_bytes(chunk[0..8].try_into().ok()?),
                a: u64::from_le_bytes(chunk[8..16].try_into().ok()?),
                b: u64::from_le_bytes(chunk[16..24].try_into().ok()?),
                track: u32::from_le_bytes(chunk[24..28].try_into().ok()?),
                kind: EventKind::from_u8(chunk[28])?,
            });
        }
        Some(TraceChunk { locality, seq, dropped, events })
    }
}

/// Append-only, fsynced spool of framed [`TraceChunk`]s for one
/// locality. [`SpoolWriter::append`] returns the chunks it framed so
/// the caller can stream the identical bytes to the parent.
pub struct SpoolWriter {
    file: std::fs::File,
    locality: u32,
    next_seq: u64,
}

/// Spool file path for `locality` under `dir`.
pub fn spool_path(dir: &Path, locality: u32) -> PathBuf {
    dir.join(format!("loc{locality}.spool"))
}

impl SpoolWriter {
    /// Create (truncate) the spool for `locality` under `dir`, creating
    /// the directory if needed.
    pub fn create(dir: &Path, locality: u32) -> std::io::Result<SpoolWriter> {
        std::fs::create_dir_all(dir)?;
        let file = std::fs::File::create(spool_path(dir, locality))?;
        Ok(SpoolWriter { file, locality, next_seq: 0 })
    }

    /// Frame `events` (split into ≤ [`MAX_EVENTS_PER_CHUNK`] batches),
    /// append to the spool, and fsync — only after the sync returns are
    /// the chunks considered durable. `dropped` rides on the first
    /// chunk. With no events and no drops this is a no-op.
    pub fn append(
        &mut self,
        events: &[Event],
        dropped: u64,
    ) -> std::io::Result<Vec<TraceChunk>> {
        use std::io::Write as _;
        if events.is_empty() && dropped == 0 {
            return Ok(Vec::new());
        }
        let mut chunks = Vec::new();
        let mut batches: Vec<&[Event]> =
            events.chunks(MAX_EVENTS_PER_CHUNK).collect();
        if batches.is_empty() {
            batches.push(&[]); // dropped-only chunk
        }
        for (i, batch) in batches.into_iter().enumerate() {
            let chunk = TraceChunk {
                locality: self.locality,
                seq: self.next_seq,
                dropped: if i == 0 { dropped } else { 0 },
                events: batch.to_vec(),
            };
            self.next_seq += 1;
            self.file.write_all(&Frame::Trace(chunk.clone()).encode())?;
            chunks.push(chunk);
        }
        self.file.sync_data()?;
        Ok(chunks)
    }
}

/// Read every intact [`TraceChunk`] frame from a spool file. A torn
/// final frame (the producer died mid-append) truncates silently to the
/// valid prefix; a missing file reads as empty.
pub fn read_spool_file(path: &Path) -> Vec<TraceChunk> {
    let Ok(bytes) = std::fs::read(path) else { return Vec::new() };
    let mut out = Vec::new();
    let mut off = 0;
    while off < bytes.len() {
        match Frame::decode(&bytes[off..]) {
            Ok((Frame::Trace(chunk), n)) => {
                out.push(chunk);
                off += n;
            }
            Ok((_, n)) => off += n, // foreign frame: skip, keep scanning
            Err(_) => break,        // torn tail from the kill: stop here
        }
    }
    out
}

/// Read every `*.spool` file under `dir`.
pub fn read_spool_dir(dir: &Path) -> Vec<TraceChunk> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Vec::new() };
    let mut out = Vec::new();
    for entry in entries.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) == Some("spool") {
            out.extend(read_spool_file(&path));
        }
    }
    out
}

/// Union of the streamed and spooled copies, deduplicated by
/// `(locality, seq)` and ordered by it — the post-mortem stitch.
pub fn merge_chunks(
    streamed: Vec<TraceChunk>,
    spooled: Vec<TraceChunk>,
) -> Vec<TraceChunk> {
    let mut by: std::collections::BTreeMap<(u32, u64), TraceChunk> = Default::default();
    for chunk in spooled.into_iter().chain(streamed) {
        by.insert((chunk.locality, chunk.seq), chunk);
    }
    by.into_values().collect()
}

/// Fold chunks into `(locality, events-in-seq-order, dropped-total)`
/// triples — the shape [`crate::trace::ingest_remote`] takes.
pub fn per_locality(chunks: Vec<TraceChunk>) -> Vec<(u32, Vec<Event>, u64)> {
    let mut by: std::collections::BTreeMap<u32, (Vec<Event>, u64)> = Default::default();
    for chunk in merge_chunks(chunks, Vec::new()) {
        let slot = by.entry(chunk.locality).or_default();
        slot.0.extend(chunk.events);
        slot.1 += chunk.dropped;
    }
    by.into_iter().map(|(loc, (events, dropped))| (loc, events, dropped)).collect()
}

/// Build exportable tracks straight from chunks (the standalone
/// `rhpx trace convert` path — no global session involved). Returns the
/// tracks and the summed producer-side dropped count.
pub fn tracks_from_chunks(chunks: Vec<TraceChunk>) -> (Vec<Track>, u64) {
    let mut tracks = Vec::new();
    let mut dropped_total = 0;
    for (loc, events, dropped) in per_locality(chunks) {
        dropped_total += dropped;
        let mut by: std::collections::BTreeMap<u32, Vec<Event>> = Default::default();
        for e in events {
            by.entry(e.track).or_default().push(e);
        }
        for (track, mut events) in by {
            events.sort_by_key(|e| e.ts_ns);
            tracks.push(Track {
                pid: WORKER_PID_BASE + loc,
                tid: track + 1,
                name: format!("loc{loc}/t{track}"),
                events,
            });
        }
    }
    (tracks, dropped_total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts_ns: u64, kind: EventKind, a: u64) -> Event {
        Event { ts_ns, kind, track: 0, a, b: 0 }
    }

    fn chunk(locality: u32, seq: u64, ids: &[u64]) -> TraceChunk {
        TraceChunk {
            locality,
            seq,
            dropped: 0,
            events: ids.iter().map(|&a| ev(a * 10, EventKind::Spawn, a)).collect(),
        }
    }

    #[test]
    fn chunk_bytes_roundtrip() {
        let c = TraceChunk {
            locality: 2,
            seq: 17,
            dropped: 3,
            events: vec![
                ev(1, EventKind::ExecBegin, 9),
                Event { ts_ns: 2, kind: EventKind::HeartbeatMiss, track: 5, a: 1, b: 4 },
            ],
        };
        assert_eq!(TraceChunk::from_bytes(&c.to_bytes()), Some(c.clone()));
        // Truncations never panic and never decode.
        let bytes = c.to_bytes();
        for cut in 0..bytes.len() {
            assert_eq!(TraceChunk::from_bytes(&bytes[..cut]), None, "cut {cut}");
        }
        // A hostile count field fails the exact-coverage check.
        let mut hostile = c.to_bytes();
        hostile[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(TraceChunk::from_bytes(&hostile), None);
        // An unknown kind byte is a decode failure, not a panic.
        let mut bad_kind = c.to_bytes();
        let kind_at = 24 + EVENT_WIRE_BYTES - 1;
        bad_kind[kind_at] = 200;
        assert_eq!(TraceChunk::from_bytes(&bad_kind), None);
    }

    #[test]
    fn writer_appends_and_reader_reads_back() {
        let dir = std::env::temp_dir().join(format!("rhpx_spool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = SpoolWriter::create(&dir, 1).expect("create");
        let events: Vec<Event> = (0..5).map(|i| ev(i, EventKind::Spawn, i)).collect();
        let first = w.append(&events, 2).expect("append");
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].seq, 0);
        assert_eq!(first[0].dropped, 2);
        let second = w.append(&events[..1], 0).expect("append");
        assert_eq!(second[0].seq, 1);
        assert!(w.append(&[], 0).expect("noop").is_empty());
        let back = read_spool_file(&spool_path(&dir, 1));
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], first[0]);
        assert_eq!(back[1], second[0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_keeps_the_valid_prefix() {
        let dir = std::env::temp_dir().join(format!("rhpx_spool_torn_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = SpoolWriter::create(&dir, 0).expect("create");
        w.append(&[ev(1, EventKind::Spawn, 1)], 0).expect("append");
        w.append(&[ev(2, EventKind::ExecBegin, 2)], 0).expect("append");
        drop(w);
        // Simulate the kill landing mid-append: chop the last frame.
        let path = spool_path(&dir, 0);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let back = read_spool_file(&path);
        assert_eq!(back.len(), 1, "valid prefix survives the torn tail");
        assert_eq!(back[0].events[0].a, 1);
        // A missing file is just empty.
        assert!(read_spool_file(Path::new("/nonexistent/x.spool")).is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_dedups_streamed_and_spooled_copies() {
        let streamed = vec![chunk(0, 0, &[1]), chunk(0, 1, &[2]), chunk(1, 0, &[5])];
        // The spool has everything the stream has, plus the chunk the
        // parent never received before the kill.
        let spooled = vec![chunk(0, 0, &[1]), chunk(0, 1, &[2]), chunk(0, 2, &[3])];
        let merged = merge_chunks(streamed, spooled);
        let keys: Vec<(u32, u64)> = merged.iter().map(|c| (c.locality, c.seq)).collect();
        assert_eq!(keys, vec![(0, 0), (0, 1), (0, 2), (1, 0)]);
        let per = per_locality(merged);
        assert_eq!(per.len(), 2);
        let loc0: Vec<u64> = per[0].1.iter().map(|e| e.a).collect();
        assert_eq!(loc0, vec![1, 2, 3], "seq order, exactly once");
    }

    #[test]
    fn tracks_from_chunks_groups_by_locality_and_track() {
        let mut c = chunk(3, 0, &[1, 2]);
        c.events[1].track = 1;
        c.dropped = 4;
        let (tracks, dropped) = tracks_from_chunks(vec![c]);
        assert_eq!(dropped, 4);
        assert_eq!(tracks.len(), 2);
        assert!(tracks.iter().all(|t| t.pid == WORKER_PID_BASE + 3));
        assert_eq!(tracks[0].name, "loc3/t0");
        assert_eq!(tracks[1].name, "loc3/t1");
    }
}
