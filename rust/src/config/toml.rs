//! A small TOML-subset parser (no external crates are available in this
//! offline build, so the config system carries its own parser).
//!
//! Supported subset — more than enough for runtime/benchmark configs:
//! `[section]` and `[section.sub]` headers; `key = value` pairs with
//! string (`"…"`), integer, float, boolean, and flat array values;
//! `#` comments; blank lines. Keys are addressed as dotted paths
//! (`section.sub.key`).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: dotted-path -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert / override an entry (used for env and CLI overrides).
    pub fn set(&mut self, path: &str, value: Value) {
        self.entries.insert(path.to_string(), value);
    }
}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (i, raw) in input.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let name = stripped.strip_suffix(']').ok_or_else(|| ParseError {
                line: lineno,
                message: "unterminated section header".into(),
            })?;
            let name = name.trim();
            if name.is_empty()
                || !name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
            {
                return Err(ParseError {
                    line: lineno,
                    message: format!("bad section name {name:?}"),
                });
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| ParseError {
            line: lineno,
            message: "expected `key = value`".into(),
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError { line: lineno, message: "empty key".into() });
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(path, value);
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, ParseError> {
    let err = |m: &str| ParseError { line: lineno, message: m.to_string() };
    if s.is_empty() {
        return Err(err("missing value"));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or_else(|| err("unterminated string"))?;
        if body.contains('"') {
            return Err(err("embedded quote in string (escapes unsupported)"));
        }
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or_else(|| err("unterminated array"))?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for item in body.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue; // trailing comma
            }
            items.push(parse_value(item, lineno)?);
        }
        return Ok(Value::Array(items));
    }
    // Numbers: underscores allowed as digit separators.
    let cleaned: String = s.chars().filter(|c| *c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        cleaned
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| err(&format!("bad float {s:?}")))
    } else {
        cleaned
            .parse::<i64>()
            .map(Value::Int)
            .map_err(|_| err(&format!("bad value {s:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse(
            r#"
# runtime settings
workers = 4
name = "rhpx"   # inline comment

[stencil]
subdomains = 128
points = 16_000
dt_factor = 0.5
resilient = true
cases = [1, 2, 3]

[stencil.replay]
attempts = 3
"#,
        )
        .unwrap();
        assert_eq!(doc.get("workers").unwrap().as_int(), Some(4));
        assert_eq!(doc.get("name").unwrap().as_str(), Some("rhpx"));
        assert_eq!(doc.get("stencil.subdomains").unwrap().as_int(), Some(128));
        assert_eq!(doc.get("stencil.points").unwrap().as_int(), Some(16000));
        assert_eq!(doc.get("stencil.dt_factor").unwrap().as_float(), Some(0.5));
        assert_eq!(doc.get("stencil.resilient").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("stencil.cases").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(doc.get("stencil.replay.attempts").unwrap().as_int(), Some(3));
    }

    #[test]
    fn int_coerces_to_float_not_reverse() {
        let doc = parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc.get("a").unwrap().as_float(), Some(3.0));
        assert_eq!(doc.get("b").unwrap().as_int(), None);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get("tag").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("x = \"oops").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn set_overrides() {
        let mut doc = parse("a = 1").unwrap();
        doc.set("a", Value::Int(2));
        assert_eq!(doc.get("a").unwrap().as_int(), Some(2));
    }
}
