//! Typed configuration for the runtime, applications, and benchmarks.
//!
//! Sources, lowest to highest precedence: built-in defaults → TOML file
//! (`--config path.toml`) → `RHPX_*` environment variables → CLI flags.
//!
//! Paper mapping: runtime plumbing (no table/figure of its own); sizes
//! the worker pools every benchmark harness runs on.

pub mod toml;

use std::path::Path;

pub use toml::{Document, ParseError, Value};

/// Runtime-level configuration (the `[runtime]` section).
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeConfig {
    /// Worker threads in the scheduler.
    pub workers: usize,
    /// Directory holding AOT-compiled `*.hlo.txt` artifacts.
    pub artifacts_dir: String,
    /// Default replay attempts used by applications when unspecified.
    pub replay_attempts: usize,
    /// Default replication factor used by applications when unspecified.
    pub replicas: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            artifacts_dir: "artifacts".to_string(),
            replay_attempts: 3,
            replicas: 3,
        }
    }
}

impl RuntimeConfig {
    /// Build from a parsed document (`[runtime]` section), then apply
    /// `RHPX_*` environment overrides.
    pub fn from_document(doc: &Document) -> Self {
        let mut c = RuntimeConfig::default();
        if let Some(v) = doc.get("runtime.workers").and_then(Value::as_int) {
            c.workers = (v.max(1)) as usize;
        }
        if let Some(v) = doc.get("runtime.artifacts_dir").and_then(Value::as_str) {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get("runtime.replay_attempts").and_then(Value::as_int) {
            c.replay_attempts = (v.max(1)) as usize;
        }
        if let Some(v) = doc.get("runtime.replicas").and_then(Value::as_int) {
            c.replicas = (v.max(1)) as usize;
        }
        c.apply_env();
        c
    }

    /// Load from a TOML file (missing file = defaults + env).
    pub fn load(path: Option<&Path>) -> Result<Self, String> {
        match path {
            None => {
                let mut c = RuntimeConfig::default();
                c.apply_env();
                Ok(c)
            }
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .map_err(|e| format!("reading {}: {e}", p.display()))?;
                let doc = toml::parse(&text).map_err(|e| e.to_string())?;
                Ok(Self::from_document(&doc))
            }
        }
    }

    /// Apply `RHPX_WORKERS`, `RHPX_ARTIFACTS_DIR`, `RHPX_REPLAY_ATTEMPTS`,
    /// `RHPX_REPLICAS`.
    pub fn apply_env(&mut self) {
        if let Ok(v) = std::env::var("RHPX_WORKERS") {
            if let Ok(n) = v.parse::<usize>() {
                self.workers = n.max(1);
            }
        }
        if let Ok(v) = std::env::var("RHPX_ARTIFACTS_DIR") {
            self.artifacts_dir = v;
        }
        if let Ok(v) = std::env::var("RHPX_REPLAY_ATTEMPTS") {
            if let Ok(n) = v.parse::<usize>() {
                self.replay_attempts = n.max(1);
            }
        }
        if let Ok(v) = std::env::var("RHPX_REPLICAS") {
            if let Ok(n) = v.parse::<usize>() {
                self.replicas = n.max(1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RuntimeConfig::default();
        assert!(c.workers >= 1);
        assert_eq!(c.replay_attempts, 3);
    }

    #[test]
    fn from_document_reads_runtime_section() {
        let doc = toml::parse(
            "[runtime]\nworkers = 7\nartifacts_dir = \"art\"\nreplay_attempts = 5\nreplicas = 4\n",
        )
        .unwrap();
        let c = RuntimeConfig::from_document(&doc);
        assert_eq!(c.workers, 7);
        assert_eq!(c.artifacts_dir, "art");
        assert_eq!(c.replay_attempts, 5);
        assert_eq!(c.replicas, 4);
    }

    #[test]
    fn load_missing_path_is_defaults() {
        let c = RuntimeConfig::load(None).unwrap();
        assert!(c.workers >= 1);
    }

    #[test]
    fn load_bad_file_errors() {
        assert!(RuntimeConfig::load(Some(Path::new("/nonexistent/x.toml"))).is_err());
    }
}
