//! Coordinated Checkpoint/Restart vs. Task Replay — the paper's §I
//! motivation, measured.
//!
//! ```sh
//! cargo run --release --offline --example checkpoint_baseline
//! ```
//!
//! Runs the same iterative stencil workload under (a) coordinated C/R
//! with global rollback (the conventional scheme) and (b) per-task
//! replay, with identical failure probabilities, and compares the amount
//! of re-executed work — the cost the paper's localized fault response
//! eliminates.

use rhpx::checkpoint::{run_with_checkpoints, CheckpointStore, Storage};
use rhpx::failure::FaultInjector;
use rhpx::metrics::{Table, Timer};
use rhpx::resilience::async_replay;
use rhpx::stencil::{build_extended, kernel, Chunk, Domain};
use rhpx::{Runtime, TaskResult};

const N_SUB: usize = 8;
const NX: usize = 512;
const STEPS: usize = 8;
const ITERATIONS: u64 = 150;

fn advance(d: &Vec<Vec<f64>>) -> Vec<Vec<f64>> {
    let chunks: Vec<Chunk> = d.iter().map(|v| Chunk::new(v.clone())).collect();
    (0..N_SUB)
        .map(|j| {
            let ext = build_extended(
                &chunks[(j + N_SUB - 1) % N_SUB],
                &chunks[j],
                &chunks[(j + 1) % N_SUB],
                STEPS,
            );
            kernel::lax_wendroff_multistep(&ext, STEPS, 0.9)
        })
        .collect()
}

fn main() {
    let p_fail = 0.03; // per-task failure probability
    let domain0 = Domain::sine(N_SUB, NX);
    let init: Vec<Vec<f64>> = domain0.subdomains.iter().map(|c| c.data.to_vec()).collect();

    println!(
        "workload: {N_SUB} subdomains x {NX} pts, {ITERATIONS} iterations, \
         P(task failure) = {p_fail}\n"
    );

    // ---------- coordinated C/R (disk-backed snapshots) ----------
    let dir = std::env::temp_dir().join(format!("rhpx_cr_{}", std::process::id()));
    let store = CheckpointStore::new(Storage::Disk(dir.clone()));
    let inj_cr = FaultInjector::with_probability(p_fail, 42);
    let mut state = init.clone();
    let t = Timer::start();
    let cr = run_with_checkpoints(&mut state, ITERATIONS, 10, &store, |_, s| {
        for _ in 0..N_SUB {
            inj_cr.draw("cr-task")?; // any task failing fails the iteration
        }
        *s = advance(s);
        Ok(())
    })
    .expect("C/R run failed");
    let cr_secs = t.elapsed_secs();
    let cr_state = state.clone();
    let _ = std::fs::remove_dir_all(&dir);

    // ---------- task replay ----------
    let rt = Runtime::builder().build();
    let inj_replay = FaultInjector::with_probability(p_fail, 42);
    let mut replay_state = init.clone();
    let t = Timer::start();
    for _ in 0..ITERATIONS {
        // each subdomain task individually replays on failure
        let next: Vec<_> = (0..N_SUB)
            .map(|_| {
                let inj = inj_replay.clone();
                async_replay(&rt, 50, move || -> TaskResult<()> {
                    inj.draw("replay-task")?;
                    Ok(())
                })
            })
            .collect();
        for f in next {
            f.get().expect("replay exhausted");
        }
        replay_state = advance(&replay_state);
    }
    let replay_secs = t.elapsed_secs();

    assert_eq!(cr_state, replay_state, "schemes must agree on the result");

    let cr_redone_tasks = cr.redone * N_SUB as u64;
    let replay_redone_tasks = inj_replay.counters().injected();

    let mut table = Table::new(
        "re-executed work: coordinated C/R vs task replay (identical failures)",
        &["scheme", "wall_s", "rollbacks", "redone_task_equivalents", "checkpoints"],
    );
    table.add([
        "coordinated C/R".to_string(),
        format!("{cr_secs:.3}"),
        cr.rollbacks.to_string(),
        cr_redone_tasks.to_string(),
        cr.checkpoints.to_string(),
    ]);
    table.add([
        "task replay".to_string(),
        format!("{replay_secs:.3}"),
        "0".to_string(),
        replay_redone_tasks.to_string(),
        "0".to_string(),
    ]);
    print!("{}", table.render());
    if replay_redone_tasks > 0 {
        println!(
            "\ntask replay redid {}x less work than coordinated C/R ✓",
            cr_redone_tasks.max(1) / replay_redone_tasks.max(1)
        );
    }
}
