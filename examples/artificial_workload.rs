//! The paper's artificial workload (§V-A): grain-controlled tasks with
//! exponential-model error injection, across every API variant.
//!
//! ```sh
//! cargo run --release --offline --example artificial_workload [-- tasks grain_us]
//! ```

use rhpx::metrics::Table;
use rhpx::workload::{run, Variant, WorkloadParams};
use rhpx::Runtime;

fn main() {
    let mut args = std::env::args().skip(1);
    let tasks: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let grain_us: u64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(200);

    let rt = Runtime::builder().build();
    println!(
        "artificial workload: {tasks} tasks x {grain_us}µs grain on {} workers\n",
        rt.workers()
    );

    let mut table = Table::new(
        "per-task cost by variant and error probability",
        &["variant", "P(error)", "per_task_us", "overhead_us", "injected", "launch_errors"],
    );

    for p_pct in [0.0f64, 1.0, 5.0] {
        let p: f64 = p_pct / 100.0;
        let params = WorkloadParams {
            tasks,
            grain_ns: grain_us * 1000,
            error_rate: (p > 0.0).then(|| -p.ln()),
            ..Default::default()
        };
        let mut variants = vec![Variant::Plain];
        variants.extend(Variant::table1_variants(3));
        for v in variants {
            let rep = run(&rt, v, &params);
            table.add([
                rep.variant.clone(),
                format!("{p_pct}%"),
                format!("{:.3}", rep.per_task_us),
                format!("{:.3}", rep.overhead_us),
                rep.failures_injected.to_string(),
                rep.launch_errors.to_string(),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "\nNote: replay absorbs failures at ~p x grain extra cost; replicate pays ~n x \
         grain\nunconditionally but also masks silent errors (vote variants)."
    );
}
