//! End-to-end driver: the paper's 1D stencil benchmark over the full
//! three-layer stack.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example stencil_1d [-- scale]
//! ```
//!
//! Proves all layers compose: the Lax-Wendroff kernel authored in
//! JAX/**Pallas** (L1), lowered AOT to HLO by `python/compile/aot.py`
//! (L2), is loaded and executed through **PJRT** from the **Rust** AMT
//! coordinator (L3), which schedules one dataflow task per (subdomain,
//! iteration) through each of the paper's resilient API variants — with
//! injected failures — and reports the paper's headline metric: % extra
//! execution time of each resilient variant over pure dataflow
//! (Table II / Fig 3).
//!
//! Numerics are validated online: at Courant = 1 the scheme is an exact
//! grid shift, so the driver checks the final state against the
//! analytically shifted initial profile after every configuration.

use std::path::Path;

use rhpx::metrics::Table;
use rhpx::runtime::ArtifactStore;
use rhpx::stencil::{self, Backend, Domain, Mode, StencilParams};
use rhpx::Runtime;

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.002);

    let rt = Runtime::builder().build();

    // Scaled case-A geometry, preferring the PJRT backend (real AOT
    // kernel) and degrading to the native Rust kernel — with a note —
    // when the engine or the artifacts are missing, so the example runs
    // on a bare checkout.
    let nx = 1000;
    let steps = 16;
    let backend = if rhpx::runtime::pjrt_available() {
        let store = ArtifactStore::open(Path::new("artifacts")).expect("scan artifacts dir");
        match Backend::pjrt(&store, nx, steps) {
            Ok(b) => {
                println!("kernel backend: AOT JAX/Pallas via PJRT");
                b
            }
            Err(e) => {
                eprintln!("note: {e}\nfalling back to the native Rust kernel");
                Backend::Native
            }
        }
    } else {
        eprintln!(
            "note: PJRT engine not compiled in (needs a vendored xla dep + --features pjrt; \
             see rust/Cargo.toml); using the native Rust kernel"
        );
        Backend::Native
    };
    let base = StencilParams {
        n_sub: 16,
        nx,
        iterations: ((8192.0 * scale) as usize).max(4),
        steps,
        courant: 1.0, // exact-shift regime -> online validation
        backend,
        window: 8,
        ..StencilParams::tiny()
    };
    println!(
        "1D stencil (Lax-Wendroff): {} subdomains x {} points, {} iterations x {} steps \
         ({} tasks) on {} workers\n",
        base.n_sub,
        base.nx,
        base.iterations,
        base.steps,
        base.total_tasks(),
        rt.workers()
    );

    let domain0 = Domain::sine(base.n_sub, base.nx);
    let exact = domain0.exact_sine_shifted((base.iterations * base.steps) as f64);

    // Warmup: compile the PJRT executable on every worker thread so the
    // first measured configuration doesn't absorb compilation time.
    let warm = StencilParams { iterations: 2, ..base.clone() };
    stencil::run(&rt, &warm).expect("warmup failed");

    let configs: Vec<(&str, Mode, Option<f64>)> = vec![
        ("pure dataflow", Mode::Pure, None),
        ("replay(3), no failures", Mode::Replay { n: 3 }, None),
        ("replay_checksum(3), no failures", Mode::ReplayChecksum { n: 3 }, None),
        ("replicate(3), no failures", Mode::Replicate { n: 3 }, None),
        ("replay(5), 1% failures", Mode::Replay { n: 5 }, Some(0.01)),
        ("replay(5), 5% failures", Mode::Replay { n: 5 }, Some(0.05)),
    ];

    let mut table = Table::new(
        "resilient stencil",
        &["configuration", "wall_s", "tasks/s", "injected", "vs_pure_%", "max_err"],
    );
    let mut pure_secs = None;
    for (label, mode, p_fail) in configs {
        let params = StencilParams {
            mode,
            error_rate: p_fail.map(|p: f64| -p.ln()),
            ..base.clone()
        };
        let (out, rep) = stencil::run(&rt, &params).expect("run failed");
        assert_eq!(rep.launch_errors, 0, "{label}: resilience exhausted");
        let max_err = out
            .iter()
            .zip(exact.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err < 1e-9, "{label}: numerics diverged ({max_err:.2e})");
        if pure_secs.is_none() {
            pure_secs = Some(rep.wall_secs);
        }
        let vs = 100.0 * (rep.wall_secs - pure_secs.unwrap()) / pure_secs.unwrap();
        table.add([
            label.to_string(),
            format!("{:.3}", rep.wall_secs),
            format!("{:.0}", rep.tasks as f64 / rep.wall_secs),
            rep.failures_injected.to_string(),
            format!("{vs:+.1}"),
            format!("{max_err:.1e}"),
        ]);
    }
    print!("{}", table.render());
    println!("\nall configurations validated against the exact analytic solution ✓");
}
