//! Resilient parallel algorithms via executor policies.
//!
//! ```sh
//! cargo run --release --offline --example resilient_algorithms
//! ```
//!
//! The same `par_map_reduce` Monte-Carlo π estimation, run under three
//! launch policies: plain (fails under injected errors), task replay
//! (absorbs them), and distributed replay across simulated localities
//! with a node dying mid-computation — the generalization of the paper's
//! future-work "special executors".

use std::sync::Arc;

use rhpx::agas::LocalityId;
use rhpx::algorithms::par_map_reduce;
use rhpx::distributed::{Cluster, NetworkConfig};
use rhpx::executor::{DistributedReplayExecutor, Executor, PlainExecutor, ReplayExecutor};
use rhpx::failure::{FaultInjector, Rng};
use rhpx::metrics::Timer;
use rhpx::{Runtime, TaskResult};

const SAMPLES_PER_CELL: u64 = 20_000;
const CELLS: u64 = 64;

/// Monte-Carlo π over one seed cell; may be zapped by the injector.
fn pi_cell(seed: u64, inj: &FaultInjector) -> TaskResult<u64> {
    inj.draw("pi-cell")?;
    let mut rng = Rng::seeded(seed);
    let mut inside = 0u64;
    for _ in 0..SAMPLES_PER_CELL {
        let x = rng.next_f64();
        let y = rng.next_f64();
        if x * x + y * y <= 1.0 {
            inside += 1;
        }
    }
    Ok(inside)
}

fn estimate<E: Executor>(label: &str, ex: &E, inj: FaultInjector) {
    let timer = Timer::start();
    let result = par_map_reduce(
        ex,
        (0..CELLS).collect::<Vec<u64>>(),
        move |seed| pi_cell(*seed, &inj),
        0u64,
        |a, b| a + b,
    );
    match result {
        Ok(inside) => {
            let pi = 4.0 * inside as f64 / (CELLS * SAMPLES_PER_CELL) as f64;
            println!(
                "{label:<28} π ≈ {pi:.5}  (err {:+.5}, {:.3}s)",
                pi - std::f64::consts::PI,
                timer.elapsed_secs()
            );
        }
        Err(e) => println!("{label:<28} FAILED: {e}"),
    }
}

fn main() {
    let rt = Runtime::builder().build();
    let p_fail = 0.15; // per-chunk failure probability is substantial

    println!(
        "Monte-Carlo π: {} cells x {} samples, P(cell-task failure) = {p_fail}\n",
        CELLS, SAMPLES_PER_CELL
    );

    // 1. No resilience: the computation usually dies.
    estimate(
        "plain executor",
        &PlainExecutor::new(&rt),
        FaultInjector::with_probability(p_fail, 1),
    );

    // 2. Task replay: same algorithm, failures absorbed transparently.
    estimate(
        "replay(20) executor",
        &ReplayExecutor::new(&rt, 20),
        FaultInjector::with_probability(p_fail, 1),
    );

    // 3. Distributed replay with a node dying mid-run.
    let cluster = Cluster::new(4, 1, NetworkConfig { latency_us: 5 });
    let cl = cluster.clone();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(30));
        cl.kill(LocalityId(2));
    });
    estimate(
        "distributed replay(8), node 2 dies mid-run",
        &DistributedReplayExecutor::new(&cluster, 8),
        FaultInjector::with_probability(p_fail, 1),
    );
    killer.join().unwrap();
    let received: Vec<usize> = (0..4)
        .map(|i| cluster.locality(LocalityId(i)).messages_received())
        .collect();
    println!("\nactive messages per locality: {received:?} (node 2 stopped executing after death)");

    // The same workload, replicated with majority voting for silent errors:
    let ex = rhpx::executor::ReplicateExecutor::with_vote(
        &rt,
        3,
        Arc::new(rhpx::resilience::vote_majority),
    );
    let inj = FaultInjector::new(0.0, 0);
    let timer = Timer::start();
    let mut inside = 0u64;
    for seed in 0..CELLS {
        let inj = inj.clone();
        inside += ex.execute(move || pi_cell(seed, &inj)).get().unwrap();
    }
    let pi = 4.0 * inside as f64 / (CELLS * SAMPLES_PER_CELL) as f64;
    println!(
        "replicate(3)+vote           π ≈ {pi:.5}  (err {:+.5}, {:.3}s)",
        pi - std::f64::consts::PI,
        timer.elapsed_secs()
    );
}
