//! Distributed resiliency demo (§Future-Work, implemented): task replay
//! and replication across simulated localities, surviving node death
//! mid-run.
//!
//! ```sh
//! cargo run --release --offline --example distributed_replay
//! ```

use std::sync::Arc;

use rhpx::agas::LocalityId;
use rhpx::distributed::{
    async_replay_distributed, async_replicate_distributed, Cluster, DistBody, NetworkConfig,
};
use rhpx::metrics::Table;
use rhpx::resilience::vote_majority;

fn main() {
    let n_loc = 4;
    let cl = Cluster::new(n_loc, 1, NetworkConfig { latency_us: 20 });
    println!("cluster: {n_loc} localities, 20µs interconnect latency\n");

    let body: DistBody<usize> = Arc::new(|loc| {
        // a little work, then report where we ran
        rhpx::metrics::busy_wait_ns(50_000);
        Ok(loc.id().0)
    });

    let mut table = Table::new(
        "work placement under failures (distributed replay)",
        &["phase", "loc0", "loc1", "loc2", "loc3", "failed"],
    );

    let mut phase = |label: &str, tasks: usize| {
        let mut per_loc = vec![0usize; n_loc];
        let mut failed = 0;
        for _ in 0..tasks {
            match async_replay_distributed(&cl, n_loc, Arc::clone(&body)).get() {
                Ok(id) => per_loc[id] += 1,
                Err(_) => failed += 1,
            }
        }
        table.add([
            label.to_string(),
            per_loc[0].to_string(),
            per_loc[1].to_string(),
            per_loc[2].to_string(),
            per_loc[3].to_string(),
            failed.to_string(),
        ]);
    };

    phase("all healthy", 40);

    println!("-> killing locality 1 and 2 ...");
    cl.kill(LocalityId(1));
    cl.kill(LocalityId(2));
    phase("loc1+loc2 dead", 40);

    println!("-> reviving locality 1 ...");
    cl.revive(LocalityId(1));
    phase("loc1 rejoined", 40);

    print!("\n{}", table.render());

    // Replication with voting across localities, node 3 silently corrupt.
    let corrupt_body: DistBody<i64> = Arc::new(|loc| {
        if loc.id().0 == 3 {
            Ok(-1) // bad node: silently wrong result
        } else {
            Ok(42)
        }
    });
    let f = async_replicate_distributed(&cl, 3, Some(Arc::new(vote_majority)), corrupt_body);
    println!(
        "\nreplicate(3) across localities with a silently-corrupt node 3, majority vote: {:?}",
        f.get()
    );
}
