//! Quickstart: the resiliency API surface in two minutes.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Mirrors the paper's Listings 1 and 2: every replay/replicate variant,
//! launched over a deliberately flaky task.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use rhpx::resilience::{
    async_replay, async_replay_validate, async_replicate, async_replicate_validate,
    async_replicate_vote, async_replicate_vote_validate, dataflow_replay, vote_majority,
};
use rhpx::{async_, Runtime, TaskResult};

fn main() {
    let rt = Runtime::builder().workers(4).build();
    println!("rhpx {} — quickstart on {} workers\n", rhpx::VERSION, rt.workers());

    // A task that fails twice, then succeeds — the "transient fault".
    let attempts = Arc::new(AtomicUsize::new(0));
    let flaky = {
        let attempts = Arc::clone(&attempts);
        move || -> TaskResult<i64> {
            if attempts.fetch_add(1, Ordering::SeqCst) < 2 {
                Err("transient hardware fault".into())
            } else {
                Ok(42)
            }
        }
    };

    // --- Task Replay (Listing 1) -----------------------------------
    let f = async_replay(&rt, 5, flaky);
    println!("async_replay(5):                {:?}", f.get());
    println!("  attempts used:                {}", attempts.load(Ordering::SeqCst));

    let f = async_replay_validate(&rt, 5, |v: &i64| *v == 42, || 42i64);
    println!("async_replay_validate(5):       {:?}", f.get());

    // --- Task Replicate (Listing 2) ---------------------------------
    let f = async_replicate(&rt, 3, || 7i64);
    println!("async_replicate(3):             {:?}", f.get());

    let f = async_replicate_validate(&rt, 3, |v: &i64| *v > 0, || 7i64);
    println!("async_replicate_validate(3):    {:?}", f.get());

    // Vote masks a silently corrupted replica.
    let replica = Arc::new(AtomicUsize::new(0));
    let silently_corrupt = {
        let replica = Arc::clone(&replica);
        move || -> i64 {
            if replica.fetch_add(1, Ordering::SeqCst) == 0 {
                666 // bit-flipped result: no error raised!
            } else {
                42
            }
        }
    };
    let f = async_replicate_vote(&rt, 3, vote_majority, silently_corrupt);
    println!("async_replicate_vote(3):        {:?}  (one replica returned 666)", f.get());

    let f = async_replicate_vote_validate(&rt, 3, vote_majority, |v: &i64| *v < 100, || 42i64);
    println!("async_replicate_vote_validate:  {:?}", f.get());

    // --- Dataflow composition ---------------------------------------
    // Resilient futures are ordinary futures: feed them to dataflow.
    let a = async_(&rt, || 20i64);
    let b = async_replay(&rt, 3, || 22i64);
    let sum = dataflow_replay(&rt, 3, |v: &[i64]| v.iter().sum::<i64>(), vec![a, b]);
    println!("dataflow_replay over mixed deps: {:?}", sum.get());

    println!("\nscheduler stats: {:?}", rt.stats());
}
