# rhpx — build / verify / bench entry points.
#
# Tier-1 verification is exactly what CI runs:
#     make build test
# which is equivalent to `cargo build --release && cargo test -q`.

CARGO ?= cargo
PYTHON ?= python3
BENCHES := perf_micro table1_async_overheads fig2_error_rates table2_stencil fig3_stencil_errors ablations table_dist table_ckpt table_zoo table_serve table_proc table_obs

.PHONY: all build test docs bench bench-smoke bench-baseline bench-diff artifacts fmt fmt-check clippy clean help

all: build

help:
	@echo "targets:"
	@echo "  build       cargo build --release (lib, rhpx CLI, bench binaries)"
	@echo "  test        cargo test -q (tier-1 verify; green on a bare checkout)"
	@echo "  docs        cargo doc -D warnings + cargo test --doc (what CI's docs job runs)"
	@echo "  bench       run every bench binary, writing BENCH_<name>.json"
	@echo "  bench-smoke same, at smoke scale (seconds, what CI runs)"
	@echo "  bench-baseline capture BENCH_baseline/BENCH_perf_micro.json (full scale)"
	@echo "  bench-diff  print per-metric deltas of BENCH_*.json vs BENCH_baseline/"
	@echo "  artifacts   AOT-lower the JAX/Pallas kernels to artifacts/*.hlo.txt"
	@echo "  fmt         cargo fmt"
	@echo "  fmt-check   cargo fmt --check"
	@echo "  clippy      cargo clippy -- -D warnings"
	@echo "  clean       cargo clean + remove bench outputs"

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Docs gate: broken intra-doc links and stale examples fail the build.
docs:
	RUSTDOCFLAGS="-D warnings -D rustdoc::broken-intra-doc-links" $(CARGO) doc --no-deps
	$(CARGO) test --doc

# Full-scale benches: one BENCH_<name>.json per harness.
bench: build
	@set -e; for b in $(BENCHES); do \
		echo "== $$b =="; \
		$(CARGO) run --release --bin $$b -- --json BENCH_$$b.json; \
	done

# Smoke-scale benches (what the CI bench-smoke job runs).
bench-smoke: build
	@set -e; for b in $(BENCHES); do \
		echo "== $$b (smoke) =="; \
		$(CARGO) run --release --bin $$b -- --smoke --json BENCH_$$b.json; \
	done

# Capture the perf baseline the bench trajectory is diffed against.
# Run on the commit *before* an optimization for a true before/after.
bench-baseline: build
	mkdir -p BENCH_baseline
	$(CARGO) run --release --bin perf_micro -- --json BENCH_baseline/BENCH_perf_micro.json

# Per-metric deltas vs the committed baseline (report only, never fails).
bench-diff:
	$(CARGO) run --release --bin bench_diff -- BENCH_perf_micro.json

# AOT-lower the L1/L2 kernels to HLO text artifacts for the PJRT path.
# Requires the Python toolchain (jax); the Rust build never does.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

fmt:
	$(CARGO) fmt

fmt-check:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

clean:
	$(CARGO) clean
	rm -f BENCH_*.json bench_*.csv
