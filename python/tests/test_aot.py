"""AOT pipeline: artifacts are emitted as parseable HLO text with the
expected signature markers."""

import os

import pytest

from compile import aot


class TestAot:
    def test_artifact_name(self):
        assert aot.artifact_name(64, 4) == "stencil_nx64_s4.hlo.txt"

    def test_parse_config(self):
        assert aot.parse_config("100:8") == (100, 8)
        with pytest.raises(ValueError):
            aot.parse_config("4:100")  # steps > nx

    def test_lower_tiny_config(self):
        text = aot.lower_stencil(16, 2)
        assert text.startswith("HloModule")
        # f64 in/out with the right shapes must appear in the module text
        assert "f64[20]" in text  # ext = nx + 2*steps
        assert "f64[16]" in text  # out
        assert "f64[1]" in text  # courant / checksum

    def test_emit_writes_files(self, tmp_path):
        paths = aot.emit(str(tmp_path), [(16, 2)])
        assert len(paths) == 1
        assert os.path.exists(paths[0])
        with open(paths[0]) as f:
            assert f.read().startswith("HloModule")

    def test_default_configs_include_paper_cases(self):
        assert (16000, 128) in aot.DEFAULT_CONFIGS
        assert (8000, 128) in aot.DEFAULT_CONFIGS
