"""L2 correctness: whole-domain composition and conservation."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def sine_domain(n_sub, nx):
    total = n_sub * nx
    g = jnp.arange(total, dtype=jnp.float64)
    return jnp.sin(2 * jnp.pi * g / total).reshape(n_sub, nx)


class TestAdvanceDomain:
    def test_matches_reference(self):
        d = sine_domain(4, 32)
        c = jnp.array([0.9])
        out, cks = model.advance_domain(d, c, steps=4)
        ref_out = model.advance_domain_ref(d, c, steps=4)
        assert out.shape == d.shape
        np.testing.assert_allclose(out, ref_out, rtol=1e-12)
        np.testing.assert_allclose(cks, jnp.sum(ref_out, axis=1), rtol=1e-12)

    def test_unit_courant_shifts_globally(self):
        n_sub, nx, steps = 4, 16, 3
        d = sine_domain(n_sub, nx)
        out, _ = model.advance_domain(d, jnp.array([1.0]), steps=steps)
        flat_in = d.reshape(-1)
        flat_out = out.reshape(-1)
        np.testing.assert_allclose(flat_out, jnp.roll(flat_in, steps), atol=1e-12)

    def test_conservation_over_iterations(self):
        """Global sum is conserved by LW on a periodic domain."""
        d = sine_domain(3, 24)
        c = jnp.array([0.7])
        total0 = float(jnp.sum(d))
        for _ in range(5):
            d, _ = model.advance_domain(d, c, steps=2)
        assert abs(float(jnp.sum(d)) - total0) < 1e-10

    @pytest.mark.parametrize("steps", [1, 2, 8])
    def test_multi_iteration_equals_flat_multistep(self, steps):
        """n_sub tasks × k iterations == one global multistep run."""
        from compile.kernels import ref

        n_sub, nx, iters = 2, 32, 3
        d = sine_domain(n_sub, nx)
        c = jnp.array([0.8])
        out = d
        for _ in range(iters):
            out, _ = model.advance_domain(out, c, steps=steps)
        # global reference: extend the flat periodic array enough for all
        # steps at once
        flat = d.reshape(-1)
        g = steps * iters
        ext = jnp.concatenate([flat[-g:], flat, flat[:g]])
        expect = ref.lax_wendroff_multistep(ext, g, 0.8)
        np.testing.assert_allclose(out.reshape(-1), expect, rtol=1e-11, atol=1e-11)

    def test_build_extended_periodic(self):
        d = jnp.arange(12.0).reshape(3, 4)
        ext = model.build_extended(d, 0, nx=4, steps=2)
        np.testing.assert_allclose(ext, [10, 11, 0, 1, 2, 3, 4, 5])
