"""L1 correctness: Pallas kernel vs. the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: the Rust side
executes exactly what these tests validate (the same jitted function is
what aot.py lowers).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lax_wendroff, ref


def make_ext(rng, nx, steps, dtype=jnp.float64):
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=nx + 2 * steps), dtype=dtype)


class TestOracle:
    def test_single_step_formula(self):
        u = jnp.array([1.0, 2.0, 4.0])
        out = ref.lax_wendroff_step(u, 0.5)
        expect = 2.0 - 0.25 * (4.0 - 1.0) + 0.125 * (4.0 - 4.0 + 1.0)
        np.testing.assert_allclose(out, [expect], rtol=1e-15)

    def test_unit_courant_is_exact_shift(self):
        nx, steps = 64, 5
        u = jnp.sin(2 * jnp.pi * jnp.arange(nx) / nx)
        ext = jnp.concatenate([u[-steps:], u, u[:steps]])
        out = ref.lax_wendroff_multistep(ext, steps, 1.0)
        np.testing.assert_allclose(out, jnp.roll(u, steps), atol=1e-12)

    def test_output_shape(self):
        ext = jnp.zeros(20)
        assert ref.lax_wendroff_multistep(ext, 3, 0.5).shape == (14,)

    def test_checksum(self):
        np.testing.assert_allclose(ref.checksum(jnp.array([1.0, 2.5])), 3.5)


class TestPallasVsOracle:
    @pytest.mark.parametrize("nx,steps", [(8, 1), (64, 4), (100, 7), (1000, 16)])
    @pytest.mark.parametrize("c", [0.0, 0.5, 0.9, 1.0])
    def test_matches_reference(self, nx, steps, c):
        rng = np.random.default_rng(nx * 1000 + steps)
        ext = make_ext(rng, nx, steps)
        c_arr = jnp.array([c])
        out, ck = lax_wendroff.stencil_task(ext, c_arr, nx=nx, steps=steps)
        ref_out = ref.lax_wendroff_multistep(ext, steps, c)
        assert out.shape == (nx,)
        assert ck.shape == (1,)
        np.testing.assert_allclose(out, ref_out, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(ck[0], jnp.sum(ref_out), rtol=1e-12)

    @settings(max_examples=30, deadline=None)
    @given(
        nx=st.integers(min_value=4, max_value=256),
        steps=st.integers(min_value=1, max_value=16),
        c=st.floats(min_value=0.0, max_value=1.0),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_property_sweep(self, nx, steps, c, seed):
        """Hypothesis sweep over shapes and Courant numbers."""
        rng = np.random.default_rng(seed)
        ext = make_ext(rng, nx, steps)
        out, ck = lax_wendroff.stencil_task(
            ext, jnp.array([c]), nx=nx, steps=steps
        )
        ref_out = ref.lax_wendroff_multistep(ext, steps, c)
        np.testing.assert_allclose(out, ref_out, rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(ck[0], jnp.sum(ref_out), rtol=1e-10)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_dtype_sweep(self, dtype):
        nx, steps = 32, 3
        rng = np.random.default_rng(7)
        ext = make_ext(rng, nx, steps, dtype=dtype)
        out, ck = lax_wendroff.stencil_task(
            ext, jnp.array([0.8]), nx=nx, steps=steps
        )
        assert out.dtype == dtype
        assert ck.dtype == dtype
        tol = 1e-5 if dtype == jnp.float32 else 1e-12
        ref_out = ref.lax_wendroff_multistep(ext, steps, dtype(0.8))
        np.testing.assert_allclose(out, ref_out, rtol=tol, atol=tol)

    def test_zero_courant_is_identity_on_interior(self):
        nx, steps = 16, 2
        rng = np.random.default_rng(1)
        ext = make_ext(rng, nx, steps)
        out, _ = lax_wendroff.stencil_task(
            ext, jnp.array([0.0]), nx=nx, steps=steps
        )
        np.testing.assert_allclose(out, ext[steps:-steps], rtol=0, atol=0)

    def test_stability_under_cfl(self):
        """Max-norm must not blow up for c <= 1 on smooth data."""
        nx, steps = 128, 64
        u = jnp.sin(2 * jnp.pi * jnp.arange(nx + 2 * steps) / (nx + 2 * steps))
        out, _ = lax_wendroff.stencil_task(
            u, jnp.array([0.95]), nx=nx, steps=steps
        )
        assert float(jnp.max(jnp.abs(out))) < 1.5
