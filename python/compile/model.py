"""L2: the stencil compute graph over the L1 Pallas kernel.

Two entry points:

* :func:`stencil_task` — the per-task graph the Rust coordinator executes
  through PJRT (one subdomain, ``steps`` levels, checksum);
* :func:`advance_domain` — a whole-domain update (all subdomains through
  the kernel with periodic ghost assembly), used by the Python tests to
  validate multi-subdomain composition against a global reference.

Everything here is build-time only: ``aot.py`` lowers ``stencil_task`` to
HLO text once, and Rust never imports Python again.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import lax_wendroff, ref


def stencil_task(ext, c, *, nx, steps):
    """The per-task model: delegate to the L1 kernel."""
    return lax_wendroff.stencil_task(ext, c, nx=nx, steps=steps)


def build_extended(domain, j, *, nx, steps):
    """Extended array for subdomain ``j`` of a (n_sub, nx) domain with
    periodic neighbors (mirrors ``rust/src/stencil/domain.rs``)."""
    n_sub = domain.shape[0]
    left = domain[(j - 1) % n_sub, nx - steps:]
    right = domain[(j + 1) % n_sub, :steps]
    return jnp.concatenate([left, domain[j], right])


@functools.partial(jax.jit, static_argnames=("steps",))
def advance_domain(domain, c, *, steps):
    """Advance every subdomain one task-iteration (``steps`` levels).

    Args:
      domain: shape ``(n_sub, nx)``.
      c: Courant number, shape ``(1,)``.
    Returns:
      (new_domain, checksums) with shapes ``(n_sub, nx)`` and ``(n_sub,)``.
    """
    n_sub, nx = domain.shape

    def one(j):
        ext = build_extended(domain, j, nx=nx, steps=steps)
        out, ck = stencil_task(ext, c, nx=nx, steps=steps)
        return out, ck[0]

    outs = []
    cks = []
    for j in range(n_sub):
        o, k = one(j)
        outs.append(o)
        cks.append(k)
    return jnp.stack(outs), jnp.stack(cks)


def advance_domain_ref(domain, c, *, steps):
    """Pure-jnp whole-domain reference for :func:`advance_domain`."""
    n_sub, nx = domain.shape
    outs = []
    for j in range(n_sub):
        ext = build_extended(domain, j, nx=nx, steps=steps)
        outs.append(ref.lax_wendroff_multistep(ext, steps, c[0]))
    return jnp.stack(outs)
