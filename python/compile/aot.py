"""AOT pipeline: lower the L2 stencil task to HLO text artifacts.

HLO *text* (not ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts            # default set
    python -m compile.aot --out-dir ../artifacts --config 64:4 --config 1000:16

Each ``--config nx:steps`` emits ``stencil_nx{nx}_s{steps}.hlo.txt`` with
signature ``(ext: f64[nx+2*steps], c: f64[1]) -> (out: f64[nx], ck: f64[1])``.
"""

import argparse
import functools
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# (nx, steps) configurations compiled by default:
#  - 64:4       tiny (tests, quickstart example)
#  - 1000:16    scaled bench geometry
#  - 16000:128  paper case A
#  - 8000:128   paper case B
DEFAULT_CONFIGS = [(64, 4), (1000, 16), (500, 16), (16000, 128), (8000, 128)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stencil(nx: int, steps: int) -> str:
    """Lower stencil_task for one geometry to HLO text."""
    ext_spec = jax.ShapeDtypeStruct((nx + 2 * steps,), jnp.float64)
    c_spec = jax.ShapeDtypeStruct((1,), jnp.float64)
    fn = functools.partial(model.stencil_task, nx=nx, steps=steps)
    lowered = jax.jit(fn).lower(ext_spec, c_spec)
    return to_hlo_text(lowered)


def artifact_name(nx: int, steps: int) -> str:
    return f"stencil_nx{nx}_s{steps}.hlo.txt"


def emit(out_dir: str, configs) -> list:
    """Write artifacts that are missing or stale; returns written paths."""
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for nx, steps in configs:
        path = os.path.join(out_dir, artifact_name(nx, steps))
        text = lower_stencil(nx, steps)
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")
    return written


def parse_config(s: str):
    nx, steps = s.split(":")
    nx, steps = int(nx), int(steps)
    if steps > nx:
        raise ValueError(f"steps ({steps}) must be <= nx ({nx})")
    return nx, steps


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--config",
        action="append",
        type=parse_config,
        help="nx:steps geometry (repeatable); default = standard set",
    )
    args = ap.parse_args(argv)
    configs = args.config or DEFAULT_CONFIGS
    emit(args.out_dir, configs)
    return 0


if __name__ == "__main__":
    sys.exit(main())
