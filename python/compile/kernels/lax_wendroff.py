"""L1: the Lax-Wendroff multistep ghost-zone kernel as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's kernel
is CPU C++; here it is authored TPU-style —

* the whole extended subdomain (``nx + 2*steps`` elements) is staged into
  VMEM as a single block via ``BlockSpec`` (no blocking needed: case A's
  16256 f64 row is ~127 KiB, far under VMEM);
* all ``steps`` time levels run as an in-kernel ``fori_loop`` over the
  VMEM-resident row — the ghost-region trick means one HBM read and one
  HBM write per task regardless of ``steps``, exactly the paper's
  "multiple time steps per iteration … reducing overheads and latency";
* the update is expressed as full-row shifted adds (``jnp.roll``), which
  vectorizes onto the VPU lanes. Cells within ``s`` of the edge hold
  garbage after level ``s``, but the valid window shrinks at the same
  rate, so the final center ``nx`` slice is exact (the same argument the
  Rust kernel's shrinking-slice formulation makes explicit).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter
into plain HLO — numerically identical, TPU-shaped structurally.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ext_ref, c_ref, out_ref, ck_ref, *, nx, steps):
    """Pallas kernel body: ext (nx+2*steps,), c (1,) -> out (nx,), ck (1,)."""
    u = ext_ref[...]
    c = c_ref[0]

    def step(_, u):
        up = jnp.roll(u, -1)
        um = jnp.roll(u, 1)
        return u - 0.5 * c * (up - um) + 0.5 * c * c * (up - 2.0 * u + um)

    u = jax.lax.fori_loop(0, steps, step, u)
    out = jax.lax.dynamic_slice(u, (steps,), (nx,))
    out_ref[...] = out
    ck_ref[0] = jnp.sum(out)


@functools.partial(jax.jit, static_argnames=("nx", "steps"))
def stencil_task(ext, c, *, nx, steps):
    """Advance one subdomain by ``steps`` levels; returns (out, checksum).

    Args:
      ext: extended subdomain, shape ``(nx + 2*steps,)``.
      c: Courant number as a shape-``(1,)`` array (runtime input so one
        artifact serves every CFL setting).
      nx, steps: static geometry.
    """
    dtype = ext.dtype
    out, ck = pl.pallas_call(
        functools.partial(_kernel, nx=nx, steps=steps),
        out_shape=(
            jax.ShapeDtypeStruct((nx,), dtype),
            jax.ShapeDtypeStruct((1,), dtype),
        ),
        interpret=True,
    )(ext, c.astype(dtype))
    return out, ck
