"""Pure-jnp oracle for the Lax-Wendroff multistep kernel.

This is the correctness reference: the Pallas kernel
(``lax_wendroff.py``), the Rust native kernel
(``rust/src/stencil/kernel.rs``), and the AOT artifact executed through
PJRT must all agree with this implementation.

Scheme (linear advection ``u_t + a u_x = 0``, Courant ``c = a dt/dx``)::

    u_i' = u_i - (c/2)(u_{i+1} - u_{i-1}) + (c^2/2)(u_{i+1} - 2 u_i + u_{i-1})

A task advances ``steps`` time levels over an extended subdomain of
``nx + 2*steps`` points; each level consumes one ghost cell per side.
"""

import jax.numpy as jnp


def lax_wendroff_step(u, c):
    """One Lax-Wendroff level over the interior (shrinks by one per side)."""
    um = u[:-2]
    u0 = u[1:-1]
    up = u[2:]
    return u0 - 0.5 * c * (up - um) + 0.5 * c * c * (up - 2.0 * u0 + um)


def lax_wendroff_multistep(ext, steps, c):
    """Advance ``steps`` levels; input (nx + 2*steps,) -> output (nx,)."""
    u = ext
    for _ in range(steps):
        u = lax_wendroff_step(u, c)
    return u


def checksum(u):
    """Task-output checksum (plain sum, Teranishi-style)."""
    return jnp.sum(u)


def stencil_task(ext, c, steps):
    """The full task payload: advanced subdomain plus its checksum."""
    out = lax_wendroff_multistep(ext, steps, c)
    return out, checksum(out)
