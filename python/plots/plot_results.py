#!/usr/bin/env python
"""Regenerate the paper's figures from the benchmark CSVs.

The Rust harnesses write ``results/bench_*.csv`` (``cargo bench`` or
``rhpx bench ... --csv``); this script renders the same graphs the paper
shows (Fig 2a, 2b, 3a, 3b) plus Table-shaped bar charts.

Usage::

    python python/plots/plot_results.py [results_dir] [out_dir]
"""

import csv
import os
import sys


def read_csv(path):
    with open(path) as f:
        rows = list(csv.DictReader(f))
    return rows


def maybe(path):
    return read_csv(path) if os.path.exists(path) else None


def plot_fig2(rows, out_dir, plt):
    xs = [float(r["error_prob_pct"]) for r in rows]
    # Fig 2a: replay
    plt.figure(figsize=(6, 4))
    plt.plot(xs, [float(r["replay3_extra_us"]) for r in rows], "o-", label="async_replay(3)")
    plt.xlabel("Probability of error occurrence per task (%)")
    plt.ylabel("Extra execution time per task (µs)")
    plt.title("Fig 2a: Async Replay — extra time vs error probability")
    plt.grid(True, alpha=0.3)
    plt.legend()
    plt.savefig(os.path.join(out_dir, "fig2a_replay.png"), dpi=120, bbox_inches="tight")
    plt.close()
    # Fig 2b: replicate
    plt.figure(figsize=(6, 4))
    plt.plot(
        xs,
        [float(r["replicate3_extra_us"]) for r in rows],
        "s-",
        color="tab:orange",
        label="async_replicate(3)",
    )
    plt.xlabel("Probability of error occurrence per task (%)")
    plt.ylabel("Extra execution time per task (µs)")
    plt.title("Fig 2b: Async Replicate — flat in error probability")
    plt.grid(True, alpha=0.3)
    plt.legend()
    plt.savefig(os.path.join(out_dir, "fig2b_replicate.png"), dpi=120, bbox_inches="tight")
    plt.close()
    print("wrote fig2a_replay.png, fig2b_replicate.png")


def plot_fig3(rows, out_dir, plt):
    cases = sorted({r["case"] for r in rows})
    for tag, case in zip("ab", cases):
        sub = [r for r in rows if r["case"] == case]
        xs = [float(r["error_prob_pct"]) for r in sub]
        plt.figure(figsize=(6, 4))
        plt.plot(xs, [float(r["replay_pct"]) for r in sub], "o-", label="replay")
        plt.plot(
            xs, [float(r["replay_checksum_pct"]) for r in sub], "s-", label="replay + checksums"
        )
        plt.xlabel("Probability of error occurrence per task (%)")
        plt.ylabel("Extra execution time (%)")
        plt.title(f"Fig 3{tag}: 1D stencil {case}")
        plt.grid(True, alpha=0.3)
        plt.legend()
        plt.savefig(
            os.path.join(out_dir, f"fig3{tag}_{case.replace('(', '_').replace(')', '')}.png"),
            dpi=120,
            bbox_inches="tight",
        )
        plt.close()
    print("wrote fig3 plots")


def plot_table1(rows, out_dir, plt):
    cores = [r["cores"] for r in rows]
    series = [k for k in rows[0] if k != "cores"]
    plt.figure(figsize=(7, 4))
    for s in series:
        plt.plot(cores, [float(r[s]) for r in rows], "o-", label=s)
    plt.xlabel("Cores")
    plt.ylabel("Amortized overhead per task (µs)")
    plt.title("Table I: resilient async overheads vs cores (200µs grain)")
    plt.grid(True, alpha=0.3)
    plt.legend(fontsize=7)
    plt.savefig(os.path.join(out_dir, "table1_overheads.png"), dpi=120, bbox_inches="tight")
    plt.close()
    print("wrote table1_overheads.png")


def plot_table2(rows, out_dir, plt):
    modes = [k for k in rows[0] if k != "case"]
    cases = [r["case"] for r in rows]
    width = 0.8 / len(modes)
    plt.figure(figsize=(7, 4))
    for i, m in enumerate(modes):
        xs = [j + i * width for j in range(len(cases))]
        plt.bar(xs, [float(r[m]) for r in rows], width=width, label=m)
    plt.xticks([j + 0.4 - width / 2 for j in range(len(cases))], cases)
    plt.ylabel("Execution time (s)")
    plt.title("Table II: 1D stencil wall time, no failures")
    plt.grid(True, axis="y", alpha=0.3)
    plt.legend(fontsize=8)
    plt.savefig(os.path.join(out_dir, "table2_stencil.png"), dpi=120, bbox_inches="tight")
    plt.close()
    print("wrote table2_stencil.png")


def main():
    results = sys.argv[1] if len(sys.argv) > 1 else "results"
    out_dir = sys.argv[2] if len(sys.argv) > 2 else os.path.join(results, "graphs")
    os.makedirs(out_dir, exist_ok=True)

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    any_plotted = False
    rows = maybe(os.path.join(results, "bench_fig2.csv"))
    if rows:
        plot_fig2(rows, out_dir, plt)
        any_plotted = True
    rows = maybe(os.path.join(results, "bench_fig3.csv"))
    if rows:
        plot_fig3(rows, out_dir, plt)
        any_plotted = True
    rows = maybe(os.path.join(results, "bench_table1.csv"))
    if rows:
        plot_table1(rows, out_dir, plt)
        any_plotted = True
    rows = maybe(os.path.join(results, "bench_table2.csv"))
    if rows:
        plot_table2(rows, out_dir, plt)
        any_plotted = True
    if not any_plotted:
        print(f"no bench_*.csv found under {results}/ — run `cargo bench` first")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
